"""§Perf hillclimb driver: run a (arch × shape) case with a set of levers and
print the before/after roofline comparison against the tagged baseline.

    PYTHONPATH=src python scripts/perf_pass.py deepseek_v2_236b train_4k \
        --opt moe_ep --tag perf1 [--mesh single_pod]

Reads the baseline record from artifacts/dryrun/baseline_<case>.json.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# must happen before any jax usage — dryrun sets XLA_FLAGS on import
from repro.launch import dryrun  # noqa: E402


def fmt(x):
    return f"{x:.3e}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--opt", action="append", default=[],
                    choices=list(dryrun.OPT_LEVERS))
    ap.add_argument("--moe-impl", default="gather")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--tag", default="perf")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    base_f = os.path.join(
        args.out, f"baseline_{args.arch}_{args.shape}_"
        f"{'multi' if args.mesh == 'multi_pod' else 'single'}.json")
    base = json.load(open(base_f)) if os.path.exists(base_f) else None
    if base is not None:
        # recompute with the CURRENT term formulas (apples-to-apples)
        from repro.launch.roofline import roofline_report
        base["roofline"] = roofline_report(base)

    rec = dryrun.run_case(args.arch, args.shape,
                          multi_pod=(args.mesh == "multi_pod"),
                          moe_impl=args.moe_impl, opts=tuple(args.opt))
    rec["tag"] = args.tag
    rec["opts"] = list(args.opt)
    out_f = os.path.join(
        args.out, f"{args.tag}_{args.arch}_{args.shape}_"
        f"{'multi' if args.mesh == 'multi_pod' else 'single'}.json")
    os.makedirs(args.out, exist_ok=True)
    with open(out_f, "w") as f:
        json.dump(rec, f, indent=1)

    if rec["status"] != "ok":
        print("FAILED:", rec.get("error"))
        print(rec.get("traceback", "")[-2000:])
        sys.exit(1)

    print(f"\n=== {args.arch} × {args.shape} × {args.mesh} "
          f"opts={args.opt or ['(baseline)']} ===")
    hdr = f"{'metric':26s} {'baseline':>12s} {'optimized':>12s} {'delta':>8s}"
    print(hdr)
    print("-" * len(hdr))

    def row(name, get):
        b = get(base) if base else float("nan")
        o = get(rec)
        delta = (o - b) / b * 100 if base and b else float("nan")
        print(f"{name:26s} {fmt(b):>12s} {fmt(o):>12s} {delta:+7.1f}%")

    row("compute_s", lambda r: r["roofline"]["compute_s"])
    row("memory_s", lambda r: r["roofline"]["memory_s"])
    row("collective_s", lambda r: r["roofline"]["collective_s"])
    row("dot_flops_tc", lambda r: r["hlo_tc"]["dot_flops_tc"])
    row("bytes_estimate_tc", lambda r: r["hlo_tc"]["bytes_estimate_tc"])
    row("collective_total_tc", lambda r: r["hlo_tc"]["collective_total_tc"])
    row("peak_bytes", lambda r: float(r["memory"]["peak_bytes"]))
    print(f"{'dominant':26s} "
          f"{(base or {}).get('roofline', {}).get('dominant', '?'):>12s} "
          f"{rec['roofline']['dominant']:>12s}")
    if base:
        bc = base.get("hlo_tc", {}).get("collective_count_tc", {})
        oc = rec.get("hlo_tc", {}).get("collective_count_tc", {})
        print(f"\ncollective counts (tc): baseline={bc}")
        print(f"                        optimized={oc}")


if __name__ == "__main__":
    main()
