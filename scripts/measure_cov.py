"""One-shot settrace-based line-coverage estimate for src/repro/core + fl.

Approximates what ``pytest --cov=repro.core --cov=repro.fl`` reports, without
needing pytest-cov in the container: traced line hits over compiled-code line
tables.  Used once to set the CI ``--cov-fail-under`` floor.

    PYTHONPATH=src python scripts/measure_cov.py
"""
from __future__ import annotations

import glob
import os
import sys
import threading
import types

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TARGETS = tuple(os.path.join(ROOT, "src", "repro", p) + os.sep
                for p in ("core", "fl"))

covered: dict = {}


def _line_tracer(frame, event, arg):
    if event == "line":
        covered.setdefault(_norm(frame.f_code.co_filename),
                           set()).add(frame.f_lineno)
    return _line_tracer


_norm_cache: dict = {}


def _norm(fn: str) -> str:
    # with a relative PYTHONPATH the interpreter records relative
    # co_filenames — normalise once per code file
    out = _norm_cache.get(fn)
    if out is None:
        out = _norm_cache[fn] = os.path.abspath(fn)
    return out


def tracer(frame, event, arg):
    if event != "call":
        return None
    fn = _norm(frame.f_code.co_filename)
    if not fn.startswith(TARGETS):
        return None
    covered.setdefault(fn, set()).add(frame.f_lineno)
    return _line_tracer


def code_lines(path: str) -> set:
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines, stack = set(), [code]
    while stack:
        c = stack.pop()
        lines.update(ln for (_s, _e, ln) in c.co_lines() if ln)
        for const in c.co_consts:
            if isinstance(const, types.CodeType):
                stack.append(const)
    return lines


def main() -> None:
    # ``python -m pytest`` puts the repo root on sys.path (tests import
    # ``benchmarks.*``); running via this script must do the same
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    sys.settrace(tracer)
    threading.settrace(tracer)
    import pytest
    rc = pytest.main(["-q", "-p", "no:cacheprovider", "tests"])
    sys.settrace(None)
    threading.settrace(None)

    total_n = hit_n = 0
    print(f"\npytest exit code: {rc}\n")
    for tgt in TARGETS:
        for path in sorted(glob.glob(tgt + "*.py")):
            want = code_lines(path)
            got = covered.get(path, set()) & want
            total_n += len(want)
            hit_n += len(got)
            pct = 100.0 * len(got) / max(len(want), 1)
            print(f"{os.path.relpath(path, ROOT):48s} "
                  f"{len(got):4d}/{len(want):4d}  {pct:5.1f}%")
    print(f"\nTOTAL core+fl: {hit_n}/{total_n} = "
          f"{100.0 * hit_n / max(total_n, 1):.1f}%")


if __name__ == "__main__":
    main()
