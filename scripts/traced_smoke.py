"""CI traced smoke: run a small mobile simulation with full telemetry on
(device attribution + per-round JSONL) and print the rendered report.

    PYTHONPATH=src python scripts/traced_smoke.py --out runs/trace_smoke

The trace lands in ``<out>/metrics.jsonl``; CI validates it with
``scripts/trace_report.py --check`` and uploads it as a workflow
artifact, so every CI run leaves an inspectable per-phase breakdown of
the event loop behind.
"""
from __future__ import annotations

import argparse
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.join(os.path.dirname(_HERE), "src"))
sys.path.insert(0, _HERE)              # sibling trace_report import


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="runs/trace_smoke",
                    help="trace output directory")
    ap.add_argument("--n-ues", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args(argv)

    from repro.config import ExperimentConfig, FLConfig, MobilityConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.fl.simulation import run_simulation
    from repro.models import build_model
    from repro.obs import Tracer
    from repro.utils.metrics import read_metrics
    from trace_report import render

    n = args.n_ues
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=max(1, n // 16),
                    staleness_bound=8, alpha=0.03, beta=0.07,
                    first_order=True,
                    inner_batch=4, outer_batch=4, hessian_batch=4),
        mobility=MobilityConfig(enabled=True, model="random_waypoint",
                                speed_mps=30.0, n_cells=3, hierarchy=True,
                                cloud_sync_every=4, step_s=0.2))
    model = build_model(cfg.model)
    clients = partition_noniid(synthetic_mnist(n=2500, seed=0), n,
                               l=4, seed=0)

    res = run_simulation(cfg, model, clients, algorithm="perfed",
                         mode="semi", bandwidth_policy="equal",
                         max_rounds=args.rounds, eval_every=2, seed=0,
                         tracer=Tracer(device=True), trace_dir=args.out)
    assert res.telemetry is not None and res.telemetry["rounds"] > 0
    print(render(read_metrics(res.telemetry["trace_path"])))
    print(f"\ntrace written to {res.telemetry['trace_path']}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
