"""Dev scratch: instantiate every family reduced, run loss + prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

rng = jax.random.PRNGKey(0)

which = sys.argv[1:] or [a for a in ARCH_IDS]
for n_arch, arch in enumerate(which):
    data_key = jax.random.fold_in(rng, n_arch)
    cfg = get_config(arch)
    if cfg.family == "small":
        model = build_model(cfg)
        params = model.init(rng)
        if arch == "char_lstm":
            batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                     "targets": jnp.ones((2, 16), jnp.int32)}
        else:
            hw = 28 if arch == "mnist_dnn" else 32
            ch = () if arch == "mnist_dnn" else (3,)
            batch = {"x": jnp.ones((2, hw, hw) + ch), "y": jnp.zeros((2,), jnp.int32)}
        loss, aux = model.loss(params, batch)
        print(f"{arch:24s} loss={float(loss):.4f}")
        continue
    red = cfg.reduced()
    model = build_model(red)
    params = model.init(rng)
    B, L = 2, 64
    if red.family == "audio":
        toks = jax.random.randint(data_key, (B, L, red.num_audio_codebooks),
                                  0, red.vocab_size)
        batch = {"tokens": toks, "targets": toks}
    else:
        toks = jax.random.randint(data_key, (B, L), 0, red.vocab_size)
        batch = {"tokens": toks, "targets": toks}
    loss, aux = model.loss(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    # prefill + decode one token
    logits_last, cache = model.prefill(params, batch["tokens"], 128)
    nxt = jnp.argmax(logits_last, -1).astype(jnp.int32)
    if red.family == "audio":
        nxt = nxt.reshape(B, 1, -1)
    else:
        nxt = nxt.reshape(B, 1)
    logits2, cache = model.decode_step(params, cache, nxt, jnp.int32(L))
    print(f"{arch:24s} loss={float(loss):.4f} decode_logits={logits2.shape}")
print("OK")
