#!/usr/bin/env python
"""simlint launcher — makes ``python scripts/simlint.py src`` work from
the repo root without an installed package or PYTHONPATH."""
import sys
from pathlib import Path

_SRC = Path(__file__).resolve().parent.parent / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
