"""Render (or validate) a simulator telemetry trace.

Reads the per-round JSONL a traced run writes (``run_simulation(...,
trace_dir=...)`` / ``cfg.obs.trace_dir``) and prints the run header, a
per-phase host/device breakdown, counter totals, and a per-round table.

    PYTHONPATH=src python scripts/trace_report.py runs/trace/metrics.jsonl
    PYTHONPATH=src python scripts/trace_report.py --check <trace.jsonl>

``--check`` validates the schema and the per-round invariants
(``obs.recorder.validate_rows``) and exits non-zero on any problem — the
CI traced-smoke step runs it against a fresh trace.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))

from repro.obs.recorder import split_rows, validate_rows
from repro.utils.metrics import read_metrics

# per-round table cap for the default rendering (full table via --rounds 0)
DEFAULT_ROUNDS_SHOWN = 30


def _fmt_s(v: float) -> str:
    return f"{v*1e3:9.2f}ms" if v < 1.0 else f"{v:9.3f}s "


def render(rows, max_rounds: int = DEFAULT_ROUNDS_SHOWN) -> str:
    meta, recs, summary = split_rows(rows)
    out = []
    if meta:
        out.append("trace: " + ", ".join(
            f"{k}={v}" for k, v in meta.items() if k != "schema"))
    if summary:
        wall = summary.get("wall_s", 0.0)
        out.append(f"rounds={summary.get('rounds')} "
                   f"arrivals={summary.get('arrivals')} "
                   f"wall={wall:.3f}s device={summary.get('device_s', 0):.3f}s")
        phases = summary.get("phase_s", {})
        if phases:
            out.append("")
            out.append("phase breakdown (exclusive host seconds):")
            tracked = sum(phases.values())
            for k, v in sorted(phases.items(), key=lambda kv: -kv[1]):
                pct = 100.0 * v / wall if wall > 0 else 0.0
                out.append(f"  {k:<14s}{_fmt_s(v)}  {pct:5.1f}% of wall")
            other = max(wall - tracked - summary.get("device_s", 0.0), 0.0)
            out.append(f"  {'(untracked)':<14s}{_fmt_s(other)}")
        dev = summary.get("device_phase_s", {})
        if dev:
            out.append("device seconds by phase:")
            for k, v in sorted(dev.items(), key=lambda kv: -kv[1]):
                out.append(f"  {k:<20s}{_fmt_s(v)}")
        counts = summary.get("counts", {})
        if counts:
            out.append("counters:")
            for k in sorted(counts):
                out.append(f"  {k:<32s}{counts[k]:>10d}")
        per_cell = summary.get("per_cell_a", {})
        if len(per_cell) > 1:
            out.append("arrivals per cell: " + ", ".join(
                f"c{c}={a}" for c, a in sorted(per_cell.items(),
                                               key=lambda kv: int(kv[0]))))
        churn = {k: summary[k] for k in ("ue_joins", "ue_departures",
                                         "label_drifts", "aborted_rounds")
                 if summary.get(k)}
        if churn:
            out.append("churn: " + ", ".join(
                f"{k}={v}" for k, v in churn.items()))
    if recs:
        # open-world traces carry live per-cell membership per round
        has_members = any("cell_members" in r for r in recs)
        out.append("")
        out.append(f"{'round':>5s} {'cell':>4s} {'a':>4s} {'heap':>5s} "
                   f"{'t_sim':>9s} {'wall_ms':>8s} {'dev_ms':>8s} "
                   f"{'disp':>5s}"
                   + ("  members" if has_members else ""))
        shown = recs if max_rounds <= 0 else recs[:max_rounds]
        for r in shown:
            line = (f"{r['round']:>5d} {r['cell']:>4d} {r['a']:>4d} "
                    f"{r['heap_depth']:>5d} {r['t_sim']:>9.2f} "
                    f"{r['wall_s']*1e3:>8.2f} {r['device_s']*1e3:>8.2f} "
                    f"{r['dispatches']:>5d}")
            if has_members:
                cm = r.get("cell_members")
                line += "  " + ("/".join(str(m) for m in cm)
                                if cm is not None else "-")
            out.append(line)
        if len(recs) > len(shown):
            out.append(f"... {len(recs) - len(shown)} more rounds "
                       f"(--rounds 0 for all)")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="path to a telemetry metrics.jsonl "
                                  "(or the directory holding one)")
    ap.add_argument("--check", action="store_true",
                    help="validate schema + invariants, no rendering")
    ap.add_argument("--rounds", type=int, default=DEFAULT_ROUNDS_SHOWN,
                    help="per-round rows to render (0 = all)")
    args = ap.parse_args(argv)

    path = args.trace
    if os.path.isdir(path):
        path = os.path.join(path, "metrics.jsonl")
    rows = read_metrics(path)

    if args.check:
        errs = validate_rows(rows)
        if errs:
            for e in errs:
                print(f"INVALID: {e}", file=sys.stderr)
            return 1
        _, recs, _ = split_rows(rows)
        print(f"OK: {path} — {len(recs)} round records, schema valid")
        return 0

    print(render(rows, max_rounds=args.rounds))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
