"""Generate EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
artifacts/dryrun/*.json.

    PYTHONPATH=src python scripts/make_tables.py [tag] > tables.md
"""
import glob
import json
import os
import sys

TAG = sys.argv[1] if len(sys.argv) > 1 else "baseline"
ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")

PARAMS = {  # (total_B, active_B)
    "starcoder2_15b": (15.2, 15.2), "mixtral_8x22b": (141.0, 39.0),
    "deepseek_67b": (67.4, 67.4), "mamba2_370m": (0.37, 0.37),
    "musicgen_large": (3.3, 3.3), "llama32_vision_11b": (10.7, 10.7),
    "deepseek_v2_236b": (236.0, 21.0), "nemotron4_15b": (15.0, 15.0),
    "yi_6b": (6.1, 6.1), "recurrentgemma_2b": (2.7, 2.7),
}
TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def main():
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, f"{TAG}_*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))

    print("### §Dry-run — lower+compile status "
          f"({sum(r['status']=='ok' for r in recs)}/{len(recs)} ok)\n")
    print("| arch | shape | mesh | status | compile | peak/dev | "
          "HLO flops/dev (tc) | collective bytes/dev (tc) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        tc = r.get("hlo_tc", {})
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
              f"{r.get('compile_s', 0):.0f}s | "
              f"{r.get('memory', {}).get('peak_bytes', 0)/2**30:.2f}GiB | "
              f"{tc.get('dot_flops_tc', 0):.3e} | "
              f"{tc.get('collective_total_tc', 0):.3e} |")

    print("\n### §Roofline — three terms per (arch × shape), single-pod "
          "(16×16 = 256 chips)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "frac | MODEL/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    from repro.launch.roofline import roofline_report
    for r in recs:
        if r["mesh"] != "single_pod" or r["status"] != "ok":
            continue
        rf = roofline_report(r)   # recompute with the latest term formulas
        tot, act = PARAMS.get(r["arch"], (0, 0))
        chips = r.get("n_devices", 256)
        mult = 6.0 if r.get("kind") == "train" else 2.0
        # perfed train ≈ 4 grad-equivalents (inner fwd+bwd≈3N, outer 3N,
        # hvp ≈ 4N) — we report plain 6ND so the ratio exposes the PerFed
        # multiplier + remat overhead explicitly
        model_fl = mult * act * 1e9 * TOKENS[r["shape"]] / chips
        flops = r.get("hlo_tc", {}).get("dot_flops_tc", 0.0)
        ratio = model_fl / flops if flops else 0.0
        note = ""
        if r["shape"] == "long_500k":
            note = {"ssm": "native O(1) state", "hybrid": "native RG-LRU"}.get(
                _family(r["arch"]), "sliding-window variant")
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
              f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
              f"{rf['dominant'].replace('_s','')} | "
              f"{rf['bound_fraction']:.2f} | {ratio:.3f} | {note} |")


def _family(arch):
    return {"mamba2_370m": "ssm", "recurrentgemma_2b": "hybrid"}.get(arch, "")


if __name__ == "__main__":
    main()
