"""§Roofline — read the dry-run artifacts and report the three terms per
(arch × shape × mesh): compute / memory / collective seconds + dominant
bottleneck + MODEL_FLOPS utilisation ratio."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")

# 6·N·D parameters (N = total params; N_active for MoE) — derived from the
# configs; used for the MODEL_FLOPS / HLO_FLOPs "useful compute" ratio.
PARAMS = {  # (total, active) in billions
    "starcoder2_15b": (15.2, 15.2),
    "mixtral_8x22b": (141.0, 39.0),
    "deepseek_67b": (67.4, 67.4),
    "mamba2_370m": (0.37, 0.37),
    "musicgen_large": (3.3, 3.3),
    "llama32_vision_11b": (10.7, 10.7),
    "deepseek_v2_236b": (236.0, 21.0),
    "nemotron4_15b": (15.0, 15.0),
    "yi_6b": (6.1, 6.1),
    "recurrentgemma_2b": (2.7, 2.7),
}

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def run() -> None:
    files = sorted(glob.glob(os.path.join(ART, "baseline_*.json")))
    if not files:
        emit("roofline/missing", 0.0,
             f"no artifacts in {ART}; run: python -m repro.launch.dryrun")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}", 0.0,
                 f"FAILED:{rec.get('error', '?')[:80]}")
            continue
        from repro.launch.roofline import roofline_report
        r = roofline_report(rec)    # recompute with current term formulas
        arch, shape = rec["arch"], rec["shape"]
        total_b, active_b = PARAMS.get(arch, (0, 0))
        chips = rec.get("n_devices", 1)
        # HLO flops are per-device; model flops per device = 6·N_active·D/chips
        # (train counts fwd+bwd ⇒ 6ND; decode fwd-only ⇒ 2ND)
        mult = 6.0 if rec.get("kind") == "train" else 2.0
        model_fl = mult * active_b * 1e9 * TOKENS.get(shape, 1) / chips
        hlo_fl = rec.get("hlo_tc", {}).get("dot_flops_tc") or rec.get("flops")
        ratio = model_fl / hlo_fl if hlo_fl else 0.0
        emit(f"roofline/{arch}/{shape}/{rec['mesh']}",
             rec.get("compile_s", 0.0) * 1e6,
             f"compute={r['compute_s']:.3e}s;memory={r['memory_s']:.3e}s;"
             f"collective={r['collective_s']:.3e}s;dominant={r['dominant']};"
             f"model_flops_ratio={ratio:.3f};"
             f"peak_GiB={rec['memory']['peak_bytes']/2**30:.2f}")
