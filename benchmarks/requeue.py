"""Requeue cost: legacy per-UE scalar pricing vs the driver's batched path.

Every time the server distributes a new model, the event loop prices one
new compute+upload cycle per requeued UE.  The pre-unification drivers did
this per UE: ``sample_fading()`` draws the whole ``[n]`` Rayleigh vector
(to use ONE element), then a ``UEChannel`` and python-scalar Eq. (10)–(11)
math — per UE per requeue.  The unified driver (``fl/driver.py``) prices a
requeue of k UEs with one ``[k, n]`` RNG draw and vectorized timing math.
Both paths are **bitwise identical** (asserted below, and pinned by
``tests/test_driver.py``); this benchmark measures the overhead win at
1024 UEs across requeue sizes.

    PYTHONPATH=src python -m benchmarks.requeue            # full sweep
    PYTHONPATH=src python -m benchmarks.requeue --smoke    # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import emit

N_UES = 1024
REQUEUE_SIZES = (8, 64, 256)
REPEATS = 50
OUT_JSON = "BENCH_requeue.json"

SMOKE_N_UES = 256
SMOKE_REQUEUE_SIZES = (16,)
SMOKE_REPEATS = 5


class PricingShim:
    """Minimal TopologyAdapter surface for ``make_cycle_duration_fn``
    (shared with ``tests/test_driver.py``)."""

    def __init__(self, net, bw):
        self.net, self.bw = net, bw

    def bind_link_budget(self, z_bits, d_i):
        pass

    def pre_requeue(self, ues):
        pass


def legacy_durations(net, wl, bw, d_i, z_bits, ues):
    """Exactly the pre-unification per-UE pricing loop — the reference the
    batched path is benchmarked against here and pinned bitwise against in
    ``tests/test_driver.py`` (one copy, imported from both)."""
    from repro.wireless.timing import compute_time, upload_time

    out = []
    for i in ues:
        h = float(net.sample_fading()[i])
        tcmp = compute_time(wl.cpu_cycles_per_sample, int(d_i[i]),
                            float(net.cpu_freq[i]))
        tcom = upload_time(z_bits, float(bw[i]), net.channel(i, h))
        out.append(tcmp + tcom)
    return np.array(out)


def run(smoke: bool = False) -> None:
    from repro.config import WirelessConfig
    from repro.fl.driver import make_cycle_duration_fn
    from repro.wireless.channel import EdgeNetwork

    n_ues = SMOKE_N_UES if smoke else N_UES
    sizes = SMOKE_REQUEUE_SIZES if smoke else REQUEUE_SIZES
    repeats = SMOKE_REPEATS if smoke else REPEATS

    wl = WirelessConfig()
    bw = np.full(n_ues, wl.total_bandwidth_hz / n_ues)
    d_i = np.full(n_ues, 48)
    z_bits = 1e6                       # ~31k fp32 params, order of mnist_dnn
    results = {"n_ues": n_ues, "repeats": repeats, "smoke": smoke,
               "sweep": []}
    rng = np.random.default_rng(0)

    for k in sizes:
        ues = rng.choice(n_ues, size=k, replace=False)
        # twin networks with identical seeds → identical RNG streams, so the
        # two paths can be timed AND checked bitwise against each other
        net_l = EdgeNetwork.drop(wl, n_ues, seed=1)
        net_b = EdgeNetwork.drop(wl, n_ues, seed=1)
        batched_fn = make_cycle_duration_fn(PricingShim(net_b, bw), wl,
                                            z_bits, d_i)

        t0 = time.perf_counter()
        for _ in range(repeats):
            want = legacy_durations(net_l, wl, bw, d_i, z_bits, ues)
        legacy_us = (time.perf_counter() - t0) / repeats * 1e6

        t0 = time.perf_counter()
        for _ in range(repeats):
            got = batched_fn(ues)
        batched_us = (time.perf_counter() - t0) / repeats * 1e6

        np.testing.assert_array_equal(got, want)   # bitwise, always
        speedup = legacy_us / max(batched_us, 1e-9)
        results["sweep"].append({
            "requeue_size": int(k), "legacy_us": legacy_us,
            "batched_us": batched_us, "speedup": speedup})
        emit(f"requeue/k={k}/n={n_ues}", batched_us,
             f"legacy_us={legacy_us:.1f};speedup=x{speedup:.1f}")

    out = "BENCH_requeue_smoke.json" if smoke else OUT_JSON
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}", flush=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
