"""Mobile multi-cell throughput: rounds/sec across UE speed × cell count.

Sweeps the new mobility subsystem at scale (default: 1024 UEs) — static vs
vehicular UEs, single cell vs a 4-cell hierarchy — and records rounds/sec,
handover counts, and cloud merges per point.  Emits the standard CSV rows
and writes ``BENCH_mobility.json``.

    PYTHONPATH=src python -m benchmarks.mobility            # full sweep
    PYTHONPATH=src python benchmarks/mobility.py --smoke    # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit

N_UES = 1024
SPEEDS = (0.0, 20.0)         # m/s: static, vehicular
CELLS = (1, 4)
ROUNDS = 8
OUT_JSON = "BENCH_mobility.json"

SMOKE_N_UES = 64
SMOKE_SPEEDS = (30.0,)
SMOKE_CELLS = (3,)
SMOKE_ROUNDS = 4             # ≥ cloud_sync_every → exercises one merge


def _setup(n_ues: int, seed: int = 0):
    from repro.config import ExperimentConfig, FLConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.models import build_model

    # the engine_throughput regime: first-order payloads, tiny batches —
    # the mobile-edge workload where scheduling dynamics dominate
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n_ues,
                    participants_per_round=max(1, n_ues // 16),
                    staleness_bound=8, alpha=0.03, beta=0.07,
                    first_order=True,
                    inner_batch=4, outer_batch=4, hessian_batch=4))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=max(2500, 10 * n_ues), seed=seed)
    clients = partition_noniid(data, n_ues, n_labels=4, seed=seed)
    return cfg, model, clients


def _point(cfg, model, clients, *, speed: float, n_cells: int,
           rounds: int, step_s: float = 1.0) -> dict:
    import dataclasses

    from repro.config import MobilityConfig
    from repro.fl.simulation import run_simulation

    cfg = dataclasses.replace(cfg, mobility=MobilityConfig(
        enabled=True, model="random_waypoint", speed_mps=speed,
        n_cells=n_cells, hierarchy=n_cells > 1, cloud_sync_every=4,
        step_s=step_s))
    t0 = time.perf_counter()
    res = run_simulation(cfg, model, clients, algorithm="perfed",
                         mode="semi", bandwidth_policy="equal",
                         max_rounds=rounds, eval_every=0, seed=0)
    wall = time.perf_counter() - t0
    completed = int(res.pi.shape[0])      # rounds actually closed, not asked
    return {"speed_mps": speed, "n_cells": n_cells,
            "rounds_requested": rounds, "rounds": completed,
            "wall_s": wall,
            "rounds_per_sec": completed / wall,
            "handovers": res.handovers,
            "cloud_rounds": res.cloud_rounds,
            "sim_time_s": res.total_time,
            "payload_dispatches": res.payload_dispatches}


def run(smoke: bool = False) -> None:
    n_ues = SMOKE_N_UES if smoke else N_UES
    speeds = SMOKE_SPEEDS if smoke else SPEEDS
    cells = SMOKE_CELLS if smoke else CELLS
    rounds = SMOKE_ROUNDS if smoke else ROUNDS

    cfg, model, clients = _setup(n_ues)
    results = {"n_ues": n_ues, "rounds": rounds, "smoke": smoke, "sweep": []}
    for n_cells in cells:
        for speed in speeds:
            # smoke sims last ~2 simulated seconds; a sub-second mobility
            # tick keeps the UEs moving (and handovers exercised) there
            pt = _point(cfg, model, clients, speed=speed, n_cells=n_cells,
                        rounds=rounds, step_s=0.2 if smoke else 1.0)
            results["sweep"].append(pt)
            emit(f"mobility/v={speed:g}/cells={n_cells}/n={n_ues}",
                 pt["wall_s"] / max(pt["rounds"], 1) * 1e6,
                 f"rps={pt['rounds_per_sec']:.2f};"
                 f"handovers={pt['handovers']};"
                 f"cloud={pt['cloud_rounds']}")
    if not smoke:
        moving = [p for p in results["sweep"]
                  if p["speed_mps"] > 0 and p["n_cells"] > 1]
        assert any(p["handovers"] > 0 for p in moving), \
            "no handover recorded in any moving multi-cell point"
    # smoke mode must not clobber the committed full-sweep artifact
    out = "BENCH_mobility_smoke.json" if smoke else OUT_JSON
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}", flush=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
