"""Ablation: exact Eq.-7 meta-gradient vs the first-order (FO) variant.

This closes the loop on the §Perf FO lever — FO saves ~46% compute at scale
(see EXPERIMENTS.md §Perf Pair C); here we measure what it costs in
convergence on the paper-scale simulation.  (Per-FedAvg's own experiments
report FO within a small gap of exact HVP; we reproduce that.)
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.simulation import run_simulation

    for first_order in (False, True):
        cfg, model, clients = standard_fl_setup(n_ues=10, a=3, n_labels=2)
        cfg = dataclasses.replace(
            cfg, fl=dataclasses.replace(cfg.fl, first_order=first_order))
        res = run_simulation(cfg, model, clients, algorithm="perfed",
                             mode="semi", max_rounds=25, eval_every=25,
                             seed=0)
        us = res.total_time / max(res.rounds[-1], 1) * 1e6
        tag = "first_order" if first_order else "exact_hvp"
        emit(f"ablation/perfed-{tag}", us,
             f"ploss={res.losses[-1]:.4f};gloss={res.global_losses[-1]:.4f}")
