"""Fig. 3/4/5 — convergence vs simulated wall-clock for the paper's
algorithm grid (FedAvg/FedProx/PerFed × SYN/S²/ASY) on synthetic MNIST and
Shakespeare, under equal and distance-derived η."""
from __future__ import annotations

from benchmarks.common import emit, standard_fl_setup

ALGOS = [("fedavg", "sync"), ("perfed", "sync"),
         ("fedavg", "semi"), ("fedprox", "semi"), ("perfed", "semi"),
         ("fedavg", "async"), ("perfed", "async")]

ROUNDS = 30


def run() -> None:
    from repro.fl.algorithms import algorithm_name
    from repro.fl.simulation import run_simulation

    for dataset in ("mnist", "shakespeare"):
        n = 10 if dataset == "mnist" else 12
        a = 3 if dataset == "mnist" else 4
        # shakespeare (LSTM) is compile-heavy on the 1-core container: run
        # the equal-η arm only (the distance-η contrast is covered by mnist)
        eta_modes = ("equal", "distance") if dataset == "mnist" else ("equal",)
        for eta_mode in eta_modes:
            cfg, model, clients = standard_fl_setup(
                n_ues=n, a=a, dataset=dataset,
                conflict=(dataset == "mnist"))
            import dataclasses
            cfg = dataclasses.replace(
                cfg, fl=dataclasses.replace(cfg.fl, eta_mode=eta_mode))
            for algo, mode in ALGOS:
                rounds = ROUNDS if mode != "sync" else max(2, ROUNDS * a // n)
                res = run_simulation(cfg, model, clients, algorithm=algo,
                                     mode=mode, max_rounds=rounds,
                                     eval_every=rounds, seed=0)
                us = res.total_time / max(res.rounds[-1], 1) * 1e6
                emit(f"fig3-5/{dataset}/{eta_mode}/{algorithm_name(algo, mode)}",
                     us,
                     f"ploss={res.losses[-1]:.4f};gloss={res.global_losses[-1]:.4f};"
                     f"sim_T={res.total_time:.2f}s;rounds={res.rounds[-1]};"
                     f"wait={res.wait_fraction:.3f}")
