"""Shared benchmark plumbing: CSV emission + standard FL setup."""
from __future__ import annotations

import time
from typing import Callable, List

import numpy as np

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """Return (result_of_last_call, mean_us)."""
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def standard_fl_setup(n_ues: int = 10, n_labels: int = 4, a: int = 3,
                      s: int = 3,
                      seed: int = 0, dataset: str = "mnist",
                      conflict: bool = False):
    """``conflict=True`` uses per-client label permutations — the regime
    where a single global model cannot fit everyone and PFL's advantage
    exists (matches the paper's strongly heterogeneous real datasets)."""
    import numpy as np

    from repro.config import ExperimentConfig, FLConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.data.partition import ClientDataset, sequence_clients
    from repro.data.synthetic import (conflicting_label_clients,
                                      synthetic_shakespeare)
    from repro.models import build_model

    if dataset == "shakespeare":
        model_cfg = get_config("char_lstm")
        clients = sequence_clients(
            synthetic_shakespeare(n_roles=n_ues, chars_per_role=800),
            n_ues, seed=seed)
        alpha, beta = 0.03, 0.07
    elif conflict:
        model_cfg = get_config("mnist_dnn")
        shards = conflicting_label_clients(n_ues, n_per_client=250, n_swap=6,
                                           seed=seed)
        clients = []
        for ci, d in enumerate(shards):
            n_test = len(d["y"]) // 5
            clients.append(ClientDataset(
                data={k: v[n_test:] for k, v in d.items()},
                test={k: v[:n_test] for k, v in d.items()},
                labels_held=np.unique(d["y"]),
                rng=np.random.default_rng(seed * 100 + ci)))
        alpha, beta = 0.03, 0.07
    else:
        model_cfg = get_config("mnist_dnn")
        clients = partition_noniid(synthetic_mnist(n=2500, seed=seed),
                                   n_ues, n_labels=n_labels, seed=seed)
        alpha, beta = 0.03, 0.07
    cfg = ExperimentConfig(
        model=model_cfg,
        fl=FLConfig(n_ues=n_ues, participants_per_round=a, staleness_bound=s,
                    alpha=alpha, beta=beta, inner_batch=16, outer_batch=16,
                    hessian_batch=16))
    model = build_model(cfg.model)
    return cfg, model, clients
