"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the mean
wall time of one unit of work (an FL round / a kernel call); ``derived``
carries the figure's headline quantity (final loss, simulated time, roofline
term, ...).

Run all:      PYTHONPATH=src python -m benchmarks.run
Run a subset: PYTHONPATH=src python -m benchmarks.run convergence staleness
CI smoke:     PYTHONPATH=src python -m benchmarks.run --smoke [suite ...]

``--smoke`` passes ``smoke=True`` to every selected suite whose ``run``
accepts it (reduced sizes, separate ``*_smoke.json`` artifacts) and skips
suites that have no smoke mode, so the default selection stays CI-sized.
"""
from __future__ import annotations

import inspect
import sys
import traceback

SUITES = [
    "convergence",       # Fig. 3/4/5 — 6+ algorithms, loss vs simulated time
    "semi_variants",     # Fig. 6 — FedAvgS², FedProxS², PerFedS²
    "noniid",            # Fig. 7 — non-iid level l sweep
    "participants",      # Fig. 8/9 — A sweep
    "staleness",         # Fig. 10 — S sweep
    "bandwidth",         # Thm. 2/4 — allocation policies
    "allocation",        # Thm. 2 inside the mobile loop: policy × mix × speed
    "fo_ablation",       # exact Eq.-7 HVP vs first-order variant
    "kernels",           # Pallas kernels vs oracles
    "engine_throughput", # batched vs sequential simulation engine
    "mobility",          # mobile multi-cell: speed × cells at 1024 UEs
    "event_loop",        # host-vs-device split, UE-count sweep to 16384
    "requeue",           # batched vs legacy per-UE requeue pricing
    "scenarios",         # open-world churn/diurnal/flash matrix × policy
    "roofline",          # §Roofline — from dry-run artifacts
]


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    unknown = [a for a in args if a.startswith("-") and a != "--smoke"]
    if unknown:
        sys.exit(f"unknown flag(s) {unknown}; known: ['--smoke']")
    named = [a for a in args if not a.startswith("-")]
    which = named or SUITES
    header = "name,us_per_call,derived"
    print(header, flush=True)
    failures = []
    for suite in which:
        try:
            mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
            if smoke:
                if "smoke" not in inspect.signature(mod.run).parameters:
                    if named:
                        # an explicitly requested suite must not silently
                        # skip — a green CI gate that runs nothing rots
                        raise RuntimeError(
                            f"suite {suite!r} has no smoke mode")
                    print(f"# {suite}: no smoke mode, skipped", flush=True)
                    continue
                mod.run(smoke=True)
            else:
                mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append((suite, e))
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} suite(s) failed: "
              f"{[s for s, _ in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
