"""Fig. 7 — PerFedS² convergence vs the non-iid level l
(higher l = more labels per UE = less heterogeneous)."""
from __future__ import annotations

from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.simulation import run_simulation

    for n_labels in (2, 4, 6, 8):
        cfg, model, clients = standard_fl_setup(n_ues=10, n_labels=n_labels, a=3)
        res = run_simulation(cfg, model, clients, algorithm="perfed",
                             mode="semi", max_rounds=20, eval_every=20,
                             seed=0)
        us = res.total_time / max(res.rounds[-1], 1) * 1e6
        emit(f"fig7/mnist/l={n_labels}", us,
             f"ploss={res.losses[-1]:.4f};gloss={res.global_losses[-1]:.4f}")
