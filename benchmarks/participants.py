"""Fig. 8/9 — PerFedS² vs the number of participants per round A,
under equal and distance-derived η."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.simulation import run_simulation

    for eta_mode in ("equal", "distance"):
        for a in (3, 5, 8):
            cfg, model, clients = standard_fl_setup(n_ues=10, a=a)
            cfg = dataclasses.replace(
                cfg, fl=dataclasses.replace(cfg.fl, eta_mode=eta_mode))
            res = run_simulation(cfg, model, clients, algorithm="perfed",
                                 mode="semi", max_rounds=20, eval_every=20,
                                 seed=0)
            us = res.total_time / max(res.rounds[-1], 1) * 1e6
            emit(f"fig8-9/{eta_mode}/A={a}", us,
                 f"ploss={res.losses[-1]:.4f};sim_T={res.total_time:.2f}s")
