"""Engine throughput: simulated rounds/sec, sequential vs batched payloads.

Sweeps the UE count (16 / 64 / 256) with A = n/2 participants per round and
measures wall-clock rounds/sec of the full simulator loop for both payload
paths, plus the device-dispatch counts that explain the gap.  Emits CSV rows
like every other suite and writes ``BENCH_engine.json`` next to the repo
root for the acceptance gate (≥ 3× at 64 UEs).

    PYTHONPATH=src python -m benchmarks.engine_throughput
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit

UE_COUNTS = (16, 64, 256)
ROUNDS = 40            # enough rounds to amortize per-run setup (drop, Thm-4)
REPEATS = 3
OUT_JSON = "BENCH_engine.json"


def _setup(n_ues: int, seed: int = 0):
    from repro.config import ExperimentConfig, FLConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.models import build_model

    # A = n/2, tiny per-client batches, first-order meta-gradients (the
    # paper's FO variant, cf. benchmarks/fo_ablation.py): the mobile-edge
    # regime the paper targets — many concurrent uploads of cheap local
    # computations, where per-arrival dispatch overhead is exactly what the
    # batched engine eliminates.  (The exact-HVP payload is ~3× more device
    # work per lane, which shrinks the *relative* win; its equivalence is
    # covered by tests/test_engine.py.)
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n_ues, participants_per_round=max(1, n_ues // 2),
                    staleness_bound=8, alpha=0.03, beta=0.07,
                    first_order=True,
                    inner_batch=4, outer_batch=4, hessian_batch=4))
    model = build_model(cfg.model)
    # enough samples that every client shard exceeds the batch sizes —
    # keeps the whole sweep on the homogeneous-shape fused path
    data = synthetic_mnist(n=max(2500, 40 * n_ues), seed=seed)
    def make_clients():
        return partition_noniid(data, n_ues, n_labels=4, seed=seed)
    return cfg, model, make_clients


def _time_mode(cfg, model, make_clients, payload_mode: str) -> dict:
    from repro.fl.engine import SimulationEngine
    from repro.fl.simulation import run_simulation

    # one engine for warmup + measurement: jit caches (payload fn, fused
    # round fn, eval fn) persist across runs exactly as in a sweep
    engine = SimulationEngine(model, cfg.fl, "perfed",
                              payload_mode=payload_mode)
    kw = dict(algorithm="perfed", mode="semi", max_rounds=ROUNDS,
              eval_every=0, seed=0, engine=engine)   # pure loop throughput
    run_simulation(cfg, model, make_clients(), **kw)      # warm jit caches
    best, res = float("inf"), None
    for _ in range(REPEATS):                # best-of-N: dodge noisy neighbors
        t0 = time.perf_counter()
        res = run_simulation(cfg, model, make_clients(), **kw)
        best = min(best, time.perf_counter() - t0)
    rounds = int(res.rounds[-1]) if len(res.rounds) else ROUNDS
    return {"payload_mode": payload_mode,
            "wall_s": best,
            "rounds": rounds,
            "rounds_per_sec": rounds / best,
            "payload_dispatches": res.payload_dispatches,
            "payloads_computed": res.payloads_computed}


def run() -> None:
    results = {"rounds": ROUNDS, "sweep": []}
    for n in UE_COUNTS:
        cfg, model, make_clients = _setup(n)
        seq = _time_mode(cfg, model, make_clients, "sequential")
        bat = _time_mode(cfg, model, make_clients, "batched")
        speedup = bat["rounds_per_sec"] / max(seq["rounds_per_sec"], 1e-12)
        results["sweep"].append({"n_ues": n, "A": max(1, n // 2),
                                 "sequential": seq, "batched": bat,
                                 "speedup": speedup})
        for r in (seq, bat):
            emit(f"engine/{r['payload_mode']}/n={n}",
                 r["wall_s"] / max(r["rounds"], 1) * 1e6,
                 f"rps={r['rounds_per_sec']:.2f};"
                 f"dispatches={r['payload_dispatches']}")
        emit(f"engine/speedup/n={n}", 0.0, f"x{speedup:.2f}")
    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {OUT_JSON}", flush=True)


if __name__ == "__main__":
    run()
