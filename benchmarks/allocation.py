"""Per-cell radio-resource allocation in the MOBILE loop: Theorem 2 vs equal.

The static path has always benchmarked the Theorem-2 equal-finish bisection
(``benchmarks/bandwidth.py``); this sweep measures what it buys *inside the
mobile multi-cell loop*, where each cell re-solves the bisection over its
current members on every membership change (warm-started from the cell's
previous ``t_star``).  Sweeps bandwidth policy × per-cell budget mix ×
UE speed at 1024 UEs and reports the mean **simulated round wall-clock**
(total simulated time / edge rounds closed) plus host wall time per point.

Two participation regimes per (mix, speed) point:

* ``full``   — per-cell A = cell population (per-cell sync rounds): the
  round ends when *every* member finishes, which is exactly the max the
  Theorem-2 objective minimises — equal-finish should win outright.
* ``sparse`` — per-cell A ≪ population (the semi-synchronous regime): the
  round ends at the A-th *fastest* arrival, a different order statistic;
  equalising all members can trade that tail in — the sweep records how
  much, honestly, rather than only benchmarking the friendly regime.

    PYTHONPATH=src python -m benchmarks.allocation            # full sweep
    PYTHONPATH=src python benchmarks/allocation.py --smoke    # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit

N_UES = 1024
N_CELLS = 4
SPEEDS = (0.0, 20.0)         # m/s: static, vehicular
ROUNDS = 8                   # edge rounds per point
POLICIES = ("equal", "theorem2")
OUT_JSON = "BENCH_allocation.json"

SMOKE_N_UES = 64
SMOKE_N_CELLS = 2
SMOKE_SPEEDS = (0.0,)
SMOKE_ROUNDS = 4

# per-cell budget mixes [Hz]; () = legacy: every cell owns the full B
MIXES = {
    "uniform": (),
    # one 2-MHz macro + 0.5-MHz micros (the HPFL-style heterogeneous mix)
    "macro_micro": lambda k: (2e6,) + (5e5,) * (k - 1),
}


def _setup(n_ues: int, seed: int = 0):
    from repro.config import ExperimentConfig, FLConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.models import build_model

    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        # eta_mode="distance" keeps the geometric (non-uniform) drop: with
        # the equal-η uniform ring every UE sits at R/2 and any bandwidth
        # split is trivially equal-finish — there would be nothing to sweep
        fl=FLConfig(n_ues=n_ues,
                    participants_per_round=max(1, n_ues // 16),
                    staleness_bound=8, alpha=0.03, beta=0.07,
                    first_order=True, eta_mode="distance",
                    inner_batch=4, outer_batch=4, hessian_batch=4))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=max(2500, 10 * n_ues), seed=seed)
    return cfg, model, data


def _point(cfg, model, data, *, policy: str, mix: str, speed: float,
           regime: str, n_cells: int, n_ues: int, rounds: int,
           association: str = "nearest") -> dict:
    import dataclasses

    from repro.config import MobilityConfig
    from repro.data import partition_noniid
    from repro.fl.simulation import run_simulation

    budgets = MIXES[mix]
    if callable(budgets):
        budgets = budgets(n_cells)
    # full: per-cell sync (A = cell population, capped by the adapter);
    # sparse: the default ceil(A / n_cells) split of the flat A
    cell_a = n_ues if regime == "full" else 0
    mcfg = MobilityConfig(
        enabled=True, model="random_waypoint", speed_mps=speed,
        n_cells=n_cells, hierarchy=True, cloud_sync_every=4,
        cell_participants=cell_a, cell_bandwidth_hz=budgets,
        association=association)
    run_cfg = dataclasses.replace(cfg, mobility=mcfg)
    clients = partition_noniid(data, n_ues, n_labels=4, seed=0)  # fresh RNG per run
    t0 = time.perf_counter()
    res = run_simulation(run_cfg, model, clients, algorithm="perfed",
                         mode="semi", bandwidth_policy=policy,
                         max_rounds=rounds, eval_every=0, seed=0)
    wall = time.perf_counter() - t0
    completed = int(res.pi.shape[0])
    return {"policy": policy, "mix": mix, "speed_mps": speed,
            "regime": regime, "association": association,
            "n_cells": n_cells,
            "rounds": completed,
            "sim_round_s": res.total_time / max(completed, 1),
            "sim_time_s": res.total_time,
            "wall_s": wall,
            "handovers": res.handovers}


def run(smoke: bool = False) -> None:
    n_ues = SMOKE_N_UES if smoke else N_UES
    n_cells = SMOKE_N_CELLS if smoke else N_CELLS
    speeds = SMOKE_SPEEDS if smoke else SPEEDS
    rounds = SMOKE_ROUNDS if smoke else ROUNDS
    mixes = ("macro_micro",) if smoke else tuple(MIXES)
    regimes = ("full",) if smoke else ("full", "sparse")

    cfg, model, data = _setup(n_ues)
    results = {"n_ues": n_ues, "n_cells": n_cells, "rounds": rounds,
               "smoke": smoke, "sweep": []}

    def add(pt: dict) -> None:
        results["sweep"].append(pt)
        emit(f"alloc/{pt['policy']}/{pt['mix']}/{pt['regime']}"
             f"/v={pt['speed_mps']:g}/{pt['association']}",
             pt["wall_s"] * 1e6 / max(pt["rounds"], 1),
             f"sim_round_s={pt['sim_round_s']:.4f};"
             f"handovers={pt['handovers']}")

    for mix in mixes:
        for regime in regimes:
            for speed in speeds:
                for policy in POLICIES:
                    add(_point(cfg, model, data, policy=policy, mix=mix,
                               speed=speed, regime=regime, n_cells=n_cells,
                               n_ues=n_ues, rounds=rounds))
    if not smoke:
        # the association knob, quantified at the heterogeneous point
        for assoc in ("nearest", "load_aware"):
            add(_point(cfg, model, data, policy="theorem2",
                       mix="macro_micro", speed=20.0, regime="sparse",
                       n_cells=n_cells, n_ues=n_ues, rounds=rounds,
                       association=assoc))

    # headline: Theorem 2 vs equal split at matched (mix, regime, speed)
    by_key = {}
    for pt in results["sweep"]:
        if pt["association"] != "nearest":
            continue
        key = (pt["mix"], pt["regime"], pt["speed_mps"])
        by_key.setdefault(key, {})[pt["policy"]] = pt["sim_round_s"]
    wins = 0
    for key, d in sorted(by_key.items()):
        if "equal" in d and "theorem2" in d:
            x = d["equal"] / max(d["theorem2"], 1e-12)
            wins += x > 1.0
            emit(f"alloc/thm2_speedup/{key[0]}/{key[1]}/v={key[2]:g}", 0.0,
                 f"x{x:.3f}")
    assert wins >= 1, \
        "theorem2 did not beat equal split at any matched sweep point"

    out = "BENCH_allocation_smoke.json" if smoke else OUT_JSON
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}", flush=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
