"""Open-world scenario suite: policy × scenario matrix on the mobile loop.

The scenario registry below is the canonical catalogue of open-world
traffic shapes (``cfg.scenario``): a closed-world baseline, steady
Poisson churn, a diurnal load wave, a flash-crowd hotspot window, and
non-stationary label drift.  Each is run against ≥2 bandwidth policies
on the 3-cell hierarchical mobile topology and the per-point lifecycle
counters (joins / departures / drifts / aborted rounds), completion,
and wait fraction are recorded — the matrix that demonstrates the
churn-adaptive round-size clamp keeps every scenario completing.

    PYTHONPATH=src python -m benchmarks.scenarios           # full matrix
    PYTHONPATH=src python benchmarks/scenarios.py --smoke   # CI smoke

Emits the standard CSV rows and writes ``BENCH_scenarios.json``
(``BENCH_scenarios_smoke.json`` under ``--smoke``).
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):          # run as a script, not -m
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit

N_UES = 64
ROUNDS = 12
POLICIES = ("equal", "theorem2")
OUT_JSON = "BENCH_scenarios.json"

SMOKE_N_UES = 32
SMOKE_ROUNDS = 4
SMOKE_POLICIES = ("equal",)


def scenario_registry():
    """name → ``ScenarioConfig`` for every catalogued traffic shape.

    Rates are in simulated seconds (a round at this scale closes in
    ~0.2–0.5 sim-s, so per-run event counts stay O(10)).
    """
    from repro.config import ScenarioConfig
    return {
        # closed world: the scenario machinery fully disabled — the
        # baseline every open-world point is compared against
        "static": ScenarioConfig(enabled=False),
        # steady churn: Poisson joins vs per-UE exponential departures
        # (equilibrium population = arrival/departure = 20 < initial 48,
        # so cells shrink below their nominal A — the live-lock regime
        # the adaptive clamp exists for)
        "churn": ScenarioConfig(
            enabled=True, initial_active_frac=0.75,
            arrival_rate=1.0, departure_rate=0.05, min_active=8),
        # diurnal wave: the same churn modulated by a full-depth
        # sinusoidal intensity (trough ≈ 0.1×, crest ≈ 1.9× base rate)
        "diurnal": ScenarioConfig(
            enabled=True, initial_active_frac=0.75,
            arrival_rate=2.0, departure_rate=0.05, min_active=8,
            diurnal_amplitude=0.9, diurnal_period_s=4.0),
        # flash crowd: a boosted-arrival window that also retargets half
        # the live population's waypoints at the hotspot cell
        "flash_crowd": ScenarioConfig(
            enabled=True, initial_active_frac=0.6,
            arrival_rate=0.5, departure_rate=0.03, min_active=8,
            flash_time_s=0.5, flash_duration_s=2.0,
            flash_arrival_boost=6.0, flash_hotspot_cell=0,
            flash_hotspot_frac=0.5),
        # label drift: light churn plus per-UE non-stationary label
        # remapping (30% of a drifting client's labels permute)
        "drift": ScenarioConfig(
            enabled=True, initial_active_frac=0.9,
            arrival_rate=0.5, departure_rate=0.02, min_active=8,
            drift_rate=0.5, drift_frac=0.3),
    }


def _setup(n_ues: int, seed: int = 0):
    from repro.config import ExperimentConfig, FLConfig, MobilityConfig
    from repro.configs import get_config
    from repro.data import partition_noniid, synthetic_mnist
    from repro.models import build_model

    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n_ues,
                    participants_per_round=max(1, n_ues // 8),
                    staleness_bound=8, alpha=0.03, beta=0.07,
                    first_order=True,
                    inner_batch=4, outer_batch=4, hessian_batch=4),
        mobility=MobilityConfig(enabled=True, model="random_waypoint",
                                speed_mps=20.0, n_cells=3, hierarchy=True,
                                cloud_sync_every=4, step_s=0.2))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=max(1250, 10 * n_ues), seed=seed)
    clients = partition_noniid(data, n_ues, n_labels=4, seed=seed)
    return cfg, model, clients


def _point(cfg, model, clients, *, scenario, policy: str,
           rounds: int) -> dict:
    import dataclasses

    from repro.fl.simulation import run_simulation

    cfg = dataclasses.replace(cfg, scenario=scenario)
    t0 = time.perf_counter()
    res = run_simulation(cfg, model, clients, algorithm="perfed",
                         mode="semi", bandwidth_policy=policy,
                         max_rounds=rounds, eval_every=0, seed=0)
    wall = time.perf_counter() - t0
    completed = int(res.pi.shape[0])
    return {"policy": policy, "rounds_requested": rounds,
            "rounds": completed, "wall_s": wall,
            "sim_time_s": res.total_time,
            "wait_fraction": res.wait_fraction,
            "handovers": res.handovers,
            "ue_joins": res.ue_joins,
            "ue_departures": res.ue_departures,
            "label_drifts": res.label_drifts,
            "aborted_rounds": res.aborted_rounds,
            "pending_uploads": res.pending_uploads}


def run(smoke: bool = False) -> None:
    n_ues = SMOKE_N_UES if smoke else N_UES
    rounds = SMOKE_ROUNDS if smoke else ROUNDS
    policies = SMOKE_POLICIES if smoke else POLICIES
    registry = scenario_registry()
    if smoke:
        import dataclasses

        # the closed-world pin plus the churn shape that exercises every
        # lifecycle path (joins, leaves, adaptive clamp); rates are
        # boosted so events actually fire inside the ~1 simulated second
        # a 4-round smoke run spans
        registry = {"static": registry["static"],
                    "churn": dataclasses.replace(
                        registry["churn"],
                        arrival_rate=8.0, departure_rate=0.4)}

    cfg, model, clients = _setup(n_ues)
    results = {"n_ues": n_ues, "rounds": rounds, "smoke": smoke,
               "matrix": []}
    for name, scen in registry.items():
        for policy in policies:
            pt = _point(cfg, model, clients, scenario=scen,
                        policy=policy, rounds=rounds)
            pt["scenario"] = name
            results["matrix"].append(pt)
            emit(f"scenarios/{name}/bw={policy}/n={n_ues}",
                 pt["wall_s"] / max(pt["rounds"], 1) * 1e6,
                 f"rounds={pt['rounds']}/{rounds};"
                 f"joins={pt['ue_joins']};"
                 f"departs={pt['ue_departures']};"
                 f"aborted={pt['aborted_rounds']}")
            # every catalogued scenario must complete under the adaptive
            # clamp — an aborted round here is the live-lock regression
            assert pt["rounds"] == rounds, \
                f"{name}/{policy}: only {pt['rounds']}/{rounds} rounds"
            assert pt["aborted_rounds"] == 0, \
                f"{name}/{policy}: aborted {pt['aborted_rounds']} round(s)"
    if not smoke:
        churny = [p for p in results["matrix"]
                  if p["scenario"] != "static"]
        assert any(p["ue_joins"] > 0 for p in churny), "no join fired"
        assert any(p["ue_departures"] > 0 for p in churny), "no leave fired"
    # smoke mode must not clobber the committed full-matrix artifact
    out = "BENCH_scenarios_smoke.json" if smoke else OUT_JSON
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}", flush=True)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
