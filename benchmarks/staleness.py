"""Fig. 10 — PerFedS² vs the staleness threshold S (equal η, A=5)."""
from __future__ import annotations


from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.simulation import run_simulation

    for s in (1, 2, 3, 4, 5):
        cfg, model, clients = standard_fl_setup(n_ues=10, a=5, s=s)
        res = run_simulation(cfg, model, clients, algorithm="perfed",
                             mode="semi", max_rounds=20, eval_every=20,
                             seed=0)
        from repro.core.scheduler import schedule_staleness
        us = res.total_time / max(res.rounds[-1], 1) * 1e6
        tau = schedule_staleness(res.pi)
        emit(f"fig10/S={s}", us,
             f"ploss={res.losses[-1]:.4f};sim_T={res.total_time:.2f}s;"
             f"max_stale={int(tau.max())}")
