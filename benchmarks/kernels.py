"""Pallas kernels: correctness deltas vs the jnp oracles + oracle wall time.

NOTE on timing: this container runs kernels in ``interpret=True`` (Python
emulation) — wall-clock of the kernel itself is meaningless.  We therefore
report the XLA-compiled ORACLE's time as ``us_per_call`` (the baseline a TPU
kernel must beat) and put the kernel-vs-oracle max error in ``derived``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed


def run() -> None:
    from repro.kernels import ops, ref
    from repro.kernels.flash_attention import flash_attention_bhld
    from repro.kernels.fused_adam import fused_adam_flat
    from repro.kernels.stale_aggregate import stale_aggregate_flat

    rng = jax.random.PRNGKey(0)

    # flash attention
    b, hq, hkv, sl, d = 2, 8, 2, 256, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, hq, sl, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, sl, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, sl, d), jnp.float32)
    oracle = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    want, us = timed(lambda: jax.block_until_ready(oracle(q, k, v)))
    got = flash_attention_bhld(q, k, v, causal=True, block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(got - oracle(q, k, v))))
    emit("kernel/flash_attention", us, f"max_err={err:.2e};shape=b{b}h{hq}l{sl}d{d}")

    # ssd chunk scan
    from repro.models.ssm import ssd_chunked as ssd_jnp
    bs, L, H, P, N = 2, 512, 4, 16, 32
    ks = jax.random.split(jax.random.fold_in(rng, 1), 5)
    x = jax.random.normal(ks[0], (bs, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (bs, L, N))
    cm = jax.random.normal(ks[4], (bs, L, N))
    oracle2 = jax.jit(lambda *args: ssd_jnp(*args, 64))
    (y_ref, _), us = timed(
        lambda: jax.block_until_ready(oracle2(x, dt, a, bm, cm)))
    y_k, _ = ops.ssd_chunked(x, dt, a, bm, cm, 64)
    err = float(jnp.max(jnp.abs(y_k - y_ref)))
    emit("kernel/ssd_scan", us, f"max_err={err:.2e};shape=b{bs}l{L}h{H}")

    # fused adam
    n = 1 << 16
    ks = jax.random.split(jax.random.fold_in(rng, 2), 4)
    p = jax.random.normal(ks[0], (n,))
    m = jnp.zeros(n)
    vv = jnp.zeros(n)
    g = jax.random.normal(ks[1], (n,))
    oracle3 = jax.jit(lambda p, m, v, g: ref.adam_ref(
        p, m, v, g, lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, t=1))
    (rp, _, _), us = timed(lambda: jax.block_until_ready(oracle3(p, m, vv, g)))
    kp, _, _ = fused_adam_flat(p, m, vv, g, lr=1e-3, t=1)
    emit("kernel/fused_adam", us,
         f"max_err={float(jnp.max(jnp.abs(kp - rp))):.2e};n={n}")

    # stale aggregate
    c = 4
    buf = jax.random.normal(ks[2], (c, n))
    mask = jnp.array([1., 0., 1., 1.])
    oracle4 = jax.jit(lambda p, b, m: ref.stale_aggregate_ref(
        p, b, m, beta=0.07))
    want, us = timed(lambda: jax.block_until_ready(oracle4(p, buf, mask)))
    got = stale_aggregate_flat(p, buf, mask, beta=0.07)
    emit("kernel/stale_aggregate", us,
         f"max_err={float(jnp.max(jnp.abs(got - want))):.2e};c={c};n={n}")
