"""Theorems 2/4 — bandwidth allocation quality.

Compares per-round completion time of (i) Theorem-2 equal-finish optimal,
(ii) the Theorem-4 weighted-equal-rate extreme, (iii) naive equal split —
and times the allocator itself (it runs in the simulator's round loop).

Cheap enough to run as-is in CI: ``smoke=True`` runs the identical sweep
(it IS the smoke size) so ``benchmarks.run --smoke bandwidth`` exercises
the allocators on every PR instead of silently skipping them."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def run(smoke: bool = False) -> None:
    from repro.config import WirelessConfig
    from repro.core.bandwidth import (equal_finish_allocation, uplink_rate,
                                      weighted_equal_rate_allocation)
    from repro.wireless.channel import EdgeNetwork

    wcfg = WirelessConfig()
    net = EdgeNetwork.drop(wcfg, 10, seed=0)
    h = net.sample_fading()
    chans = net.channels(h)
    z = [4e5] * 10
    tcmp = [0.05 * (1 + i % 3) for i in range(10)]
    b_total = wcfg.total_bandwidth_hz

    def round_time(b):
        return max(tcmp[i] + z[i] * np.log(2) / uplink_rate(b[i], chans[i])
                   for i in range(10))

    alloc, us_opt = timed(
        lambda: equal_finish_allocation(z, tcmp, chans, b_total))
    b_opt = alloc.b
    assert alloc.converged, "Theorem-2 bisection did not converge"
    emit("thm2/equal_finish", us_opt, f"round_T={round_time(b_opt):.4f}s")

    b_eq = np.full(10, b_total / 10)
    emit("thm2/equal_split", 0.0, f"round_T={round_time(b_eq):.4f}s")

    eta = np.ones(10) / 10
    b_wer, us_wer = timed(
        lambda: weighted_equal_rate_allocation(eta, chans, b_total))
    emit("thm4/weighted_equal_rate", us_wer,
         f"round_T={round_time(b_wer):.4f}s")

    speedup = round_time(b_eq) / round_time(b_opt)
    emit("thm2/speedup_vs_equal", 0.0, f"x{speedup:.3f}")
