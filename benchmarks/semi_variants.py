"""Fig. 6 — the three semi-synchronous variants (FedAvgS², FedProxS²,
PerFedS²) head-to-head under equal and distance η.

Each algorithm gets ONE SimulationEngine shared across both η modes: the
batched payload/round jit caches compile once and serve the whole sweep.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.engine import SimulationEngine
    from repro.fl.simulation import run_simulation

    # ONE model instance for the whole sweep — engines are bound to it, and
    # run_simulation validates engine/model identity
    base_cfg, model, _ = standard_fl_setup(n_ues=10, a=3, conflict=True)
    engines = {}
    for eta_mode in ("equal", "distance"):
        # conflicting-label clients: the strongly-heterogeneous regime where
        # the paper's PFL ≻ FL gap exists (a globally-fittable task hides it)
        cfg, _, clients = standard_fl_setup(n_ues=10, a=3, conflict=True)
        cfg = dataclasses.replace(
            cfg, fl=dataclasses.replace(cfg.fl, eta_mode=eta_mode))
        for algo in ("fedavg", "fedprox", "perfed"):
            if algo not in engines:
                engines[algo] = SimulationEngine(model, base_cfg.fl, algo,
                                                 payload_mode="batched")
            res = run_simulation(cfg, model, clients, algorithm=algo,
                                 mode="semi", max_rounds=30, eval_every=30,
                                 seed=0, engine=engines[algo])
            us = res.total_time / max(res.rounds[-1], 1) * 1e6
            emit(f"fig6/{eta_mode}/{algo}S2", us,
                 f"ploss={res.losses[-1]:.4f};sim_T={res.total_time:.2f}s;"
                 f"dispatches={res.payload_dispatches}")
