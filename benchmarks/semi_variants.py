"""Fig. 6 — the three semi-synchronous variants (FedAvgS², FedProxS²,
PerFedS²) head-to-head under equal and distance η."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, standard_fl_setup


def run() -> None:
    from repro.fl.simulation import run_simulation

    for eta_mode in ("equal", "distance"):
        # conflicting-label clients: the strongly-heterogeneous regime where
        # the paper's PFL ≻ FL gap exists (a globally-fittable task hides it)
        cfg, model, clients = standard_fl_setup(n_ues=10, a=3, conflict=True)
        cfg = dataclasses.replace(
            cfg, fl=dataclasses.replace(cfg.fl, eta_mode=eta_mode))
        for algo in ("fedavg", "fedprox", "perfed"):
            res = run_simulation(cfg, model, clients, algorithm=algo,
                                 mode="semi", max_rounds=30, eval_every=30,
                                 seed=0)
            us = res.total_time / max(res.rounds[-1], 1) * 1e6
            emit(f"fig6/{eta_mode}/{algo}S2", us,
                 f"ploss={res.losses[-1]:.4f};sim_T={res.total_time:.2f}s")
