"""Hypothesis property tests on the event-driven simulator's invariants."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model

_DATA = synthetic_mnist(n=600, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _run(n, a, s, mode, seed, rounds=6, bandwidth="optimal"):
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8))
    clients = partition_noniid(_DATA, n, n_labels=4, seed=seed)
    return run_simulation(cfg, _MODEL, clients, algorithm="perfed",
                          mode=mode, bandwidth_policy=bandwidth,
                          max_rounds=rounds, eval_every=100, seed=seed)


@given(st.integers(4, 8), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 3))
@settings(max_examples=8, deadline=None)
def test_invariants_semi(n, a, s, seed):
    a = min(a, n)
    res = _run(n, a, s, "semi", seed)
    # Eq. (14): every realised round has exactly A participants
    assert (res.pi.sum(1) == a).all()
    # total arrivals = A · K
    assert res.pi.sum() == a * res.pi.shape[0]
    # η sums to 1 and wall clock is positive & monotone
    assert abs(res.eta_realised.sum() - 1) < 1e-9
    assert res.total_time > 0
    assert (np.diff(res.times) >= -1e-12).all()
    # wait fraction is a valid fraction
    assert 0.0 <= res.wait_fraction < 1.0


@given(st.integers(4, 6), st.integers(0, 2))
@settings(max_examples=5, deadline=None)
def test_sync_rounds_include_everyone(n, seed):
    res = _run(n, n, 10, "sync", seed, rounds=3)
    assert (res.pi.sum(1) == n).all()


@given(st.integers(0, 2))
@settings(max_examples=3, deadline=None)
def test_async_one_per_round(seed):
    res = _run(6, 1, 10, "async", seed, rounds=8)
    assert (res.pi.sum(1) == 1).all()


def test_participation_gap_bounded_when_S_large():
    """With S ≥ n/A no in-flight work is abandoned, UEs cycle periodically
    (Theorem 3) and the participation gap stays ≤ ~n/A + flight slack."""
    from repro.core.scheduler import schedule_staleness
    n, a, s = 8, 2, 10
    res = _run(n, a, s, "semi", seed=5, rounds=20)
    tau = schedule_staleness(res.pi)
    part_tau = tau[res.pi == 1]
    assert part_tau.max() <= n // a + 2      # period n/A plus flight slack


def test_small_S_abandons_work():
    """C1.5 phenomenon: S below the natural period forces refresh cascades —
    realised wait/abandonment appears (the Fig.-10 'small S hurts' effect)."""
    res_small = _run(8, 2, 1, "semi", seed=3, rounds=16)
    res_large = _run(8, 2, 10, "semi", seed=3, rounds=16)
    # both still satisfy the Π invariant and advance the clock
    assert (res_small.pi.sum(1) == 2).all()
    assert res_small.total_time >= res_large.total_time * 0.5
