"""Paper §II-B generalisation: per-UE inner learning rates α_i ≥ 0."""
import numpy as np

from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model


def test_diverse_alpha_converges():
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=8, participants_per_round=3, staleness_bound=3,
                    alpha=0.03, alpha_spread=1.0, beta=0.07,
                    inner_batch=16, outer_batch=16, hessian_batch=16))
    model = build_model(cfg.model)
    clients = partition_noniid(synthetic_mnist(n=1600, seed=11), 8, n_labels=4,
                               seed=11)
    res = run_simulation(cfg, model, clients, algorithm="perfed", mode="semi",
                         max_rounds=15, eval_every=15, seed=11)
    assert res.losses[-1] < res.losses[0]
    assert np.isfinite(res.losses[-1])


def test_payload_fn_traced_alpha_no_recompile():
    """One compiled payload serves every α_i (traced scalar argument)."""
    import jax
    from repro.fl.client import make_payload_fn

    cfg = ExperimentConfig(model=get_config("mnist_dnn"))
    model = build_model(cfg.model)
    fn = make_payload_fn(model, cfg.fl, "perfed")
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    kx, ky = jax.random.split(jax.random.fold_in(rng, 1))
    batch = {"x": jax.random.normal(kx, (8, 28, 28)),
             "y": jax.random.randint(ky, (8,), 0, 10)}
    batches = {"inner": batch, "outer": batch, "hessian": batch}
    g1 = fn(params, batches, rng, 0.01)
    g2 = fn(params, batches, rng, 0.05)
    # different α must change the meta-gradient (Hessian term scales with α)
    d = jax.tree.map(lambda a, b: float(abs(a - b).max()), g1, g2)
    assert max(jax.tree.leaves(d)) > 0
    assert fn._cache_size() == 1     # single compilation for both α values
