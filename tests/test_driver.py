"""Unified event-loop driver: golden-trajectory pins, batched requeue
pricing, and the handover-arrival routing / stale-drain regression tests.

The goldens were captured from the pre-unification ``fl/simulation.py``
loop (PR 2 tree) — wall-clock times are pure host-side float64 event math,
so they are pinned *bitwise* (hex); losses go through jax and are pinned to
float32-level tolerance.  If these fail, the driver changed the trajectory
of the static path, which the refactor contract forbids.
"""
import dataclasses

import numpy as np
import pytest

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          WirelessConfig)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.driver import make_cycle_duration_fn
from repro.fl.simulation import run_simulation
from repro.mobility.multicell import MultiCellNetwork
from repro.models import build_model
from repro.wireless.channel import EdgeNetwork
from repro.wireless.timing import model_bits

_DATA = synthetic_mnist(n=600, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _cfg(n=8, a=3, s=3, **fl_kw):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8, **fl_kw))


def _clients(n=8, seed=0):
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


# ---------------------------------------------------------------------------
# golden pre-refactor trajectories (bitwise on host math)
# ---------------------------------------------------------------------------

def test_static_trajectory_matches_pre_refactor_golden():
    res = run_simulation(_cfg(), _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0)
    assert [float(t).hex() for t in res.times] == [
        "0x0.0p+0", "0x1.b877293c2d615p-1",
        "0x1.ae97a23acc733p+0", "0x1.4066315c4298cp+1"]
    assert float(res.total_time).hex() == "0x1.4066315c4298cp+1"
    assert float(res.wait_fraction).hex() == "0x1.f2da4241021f8p-3"
    assert res.pi.tolist() == [
        [1, 0, 0, 1, 0, 0, 0, 1], [0, 0, 1, 0, 0, 1, 1, 0],
        [0, 1, 0, 0, 1, 0, 0, 1], [1, 0, 1, 1, 0, 0, 0, 0],
        [0, 0, 0, 0, 0, 1, 1, 1], [0, 1, 1, 0, 1, 0, 0, 0]]
    assert res.rounds.tolist() == [0, 2, 4, 6]
    # the engine's one-dispatch-per-version-group fast path must be intact
    assert res.payload_dispatches == 8
    assert res.payloads_computed == 18
    np.testing.assert_allclose(res.losses, [
        2.3583488166332245, 1.8240666687488556,
        1.4705257415771484, 1.1463348343968391], rtol=1e-6)
    np.testing.assert_allclose(res.global_losses, [
        2.7490968108177185, 2.1383248418569565,
        1.7266773730516434, 1.365978181362152], rtol=1e-6)


def test_static_sequential_distance_eta_matches_pre_refactor_golden():
    cfg = _cfg(n=6, a=2, s=2, eta_mode="distance")
    res = run_simulation(cfg, _MODEL, _clients(6, seed=4),
                         algorithm="fedavg", mode="semi", max_rounds=4,
                         eval_every=2, seed=4, bandwidth_policy="equal",
                         payload_mode="sequential")
    assert [float(t).hex() for t in res.times] == [
        "0x0.0p+0", "0x1.82c4cb3f67704p-1", "0x1.6ccf9ab27fc2cp+0"]
    assert res.pi.tolist() == [
        [0, 1, 0, 1, 0, 0], [0, 0, 1, 0, 0, 1],
        [1, 0, 0, 0, 1, 0], [0, 0, 0, 1, 0, 1]]
    assert res.payload_dispatches == 8 and res.payloads_computed == 8
    np.testing.assert_allclose(res.losses, [
        2.046475092569987, 1.5647791028022766, 1.0200251936912537],
        rtol=1e-6)


# ---------------------------------------------------------------------------
# batched requeue pricing ≡ legacy per-UE scalar loop, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_batched_cycle_durations_bitwise_equal_legacy(seed):
    from benchmarks.requeue import PricingShim, legacy_durations

    wl = WirelessConfig()
    n = 64
    net_a = EdgeNetwork.drop(wl, n, seed=seed)
    net_b = EdgeNetwork.drop(wl, n, seed=seed)
    bw = np.full(n, wl.total_bandwidth_hz / n)
    d_i = np.full(n, 24)
    params = _MODEL.init(__import__("jax").random.PRNGKey(0))
    z_bits = model_bits(params)
    fn = make_cycle_duration_fn(PricingShim(net_a, bw), wl, z_bits, d_i)
    rng = np.random.default_rng(3)
    for k in (n, 5, 1, 17):              # initial fill + assorted requeues
        ues = rng.choice(n, size=k, replace=False)
        got = fn(ues)
        want = legacy_durations(net_b, wl, bw, d_i, z_bits, ues)
        np.testing.assert_array_equal(got, want)


def test_batched_cycle_durations_track_moving_distances(seed=0):
    """When the distances array is replaced (moving mobility does this on
    every advance), the pricing must use the NEW distances — and keep the
    legacy per-UE scalar-pow cost rather than rebuilding an O(n) cache."""
    from benchmarks.requeue import PricingShim, legacy_durations

    wl = WirelessConfig()
    n = 32
    net_a = EdgeNetwork.drop(wl, n, seed=seed)
    net_b = EdgeNetwork.drop(wl, n, seed=seed)
    bw = np.full(n, wl.total_bandwidth_hz / n)
    d_i = np.full(n, 24)
    fn = make_cycle_duration_fn(PricingShim(net_a, bw), wl, 1e6, d_i)
    rng = np.random.default_rng(1)
    for step in range(4):                # replace distances between requeues
        if step:
            moved = np.maximum(net_a.distances * (1.0 + 0.1 * step), 5.0)
            net_a.distances = moved
            net_b.distances = moved.copy()
        ues = rng.choice(n, size=6, replace=False)
        got = fn(ues)
        want = legacy_durations(net_b, wl, bw, d_i, 1e6, ues)
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# handover-arrival routing + stale-drain regressions
# ---------------------------------------------------------------------------

def _mobile_cfg(n=8):
    # eta_mode="distance" keeps the geometric (non-uniform) drop: with
    # seed 0, cell 0 holds two UEs, so moving one away still lets cell 0
    # close rounds of A=2 (the second arrival being the departed upload)
    return dataclasses.replace(
        _cfg(n=n, a=4, s=6, first_order=True, eta_mode="distance"),
        mobility=MobilityConfig(enabled=True, model="static", speed_mps=0.0,
                                n_cells=2, hierarchy=True,
                                cell_participants=2, cloud_sync_every=0))


def _patch_forced_handover(monkeypatch, *, fire_on_call: int):
    """Inject one cell-0 → cell-1 handover on the Nth ``advance_to`` call
    (the driver advances once per heap pop, so N=2 lands *between two pops
    of the same drain*).  Returns the shared state dict."""
    state = {"calls": 0, "moved": None}
    orig = MultiCellNetwork.advance_to

    def patched(self, t):
        events = orig(self, t)
        state["calls"] += 1
        if state["moved"] is None and state["calls"] >= fire_on_call:
            members = np.nonzero(self.assoc == 0)[0]
            if len(members) > 1:         # keep cell 0 able to close rounds
                u = int(members[0])
                self.assoc[u] = 1
                self.handovers += 1
                state["moved"] = u
                events = events + [(u, 0, 1)]
        return events

    monkeypatch.setattr(MultiCellNetwork, "advance_to", patched)
    return state


def test_inflight_upload_routes_to_dispatching_cell(monkeypatch):
    """A UE that hands over while its upload is in flight must deliver that
    upload to the *source* cell (whose round it was computed against) via
    the departed-UE path — which pop-time association routing made dead."""
    state = _patch_forced_handover(monkeypatch, fire_on_call=1)
    res = run_simulation(_mobile_cfg(), _MODEL, _clients(), algorithm="perfed",
                         mode="semi", bandwidth_policy="equal", max_rounds=8,
                         eval_every=0, seed=0, payload_mode="sequential")
    assert state["moved"] is not None and res.handovers >= 1
    # the moved UE's in-flight upload arrived at cell 0 after the handover:
    # HierarchicalServer counted it through the departed-UE branch
    assert res.departed_arrivals >= 1
    assert res.pi.shape[0] == 8
    # liveness: the departed upload earns no redistribution from the source
    # cell, so the driver must restart the UE against its held model — it
    # participates again (in its NEW cell) instead of idling until τ > S
    assert res.pi[:, state["moved"]].sum() >= 2


def test_mid_drain_handover_keeps_round_accounting_exact(monkeypatch):
    """A handover *between two pops of the same drain* must not skew the
    per-cell arrival counting: every completed round still has exactly its
    cell's A arrivals, and the run closes all requested rounds.  (Events
    carry their dispatch cell, and ``need`` depends only on pending-upload
    counts, which mid-drain handovers never touch.)"""
    state = _patch_forced_handover(monkeypatch, fire_on_call=2)
    res = run_simulation(_mobile_cfg(), _MODEL, _clients(), algorithm="perfed",
                         mode="semi", bandwidth_policy="equal", max_rounds=6,
                         eval_every=0, seed=0)
    assert state["moved"] is not None
    assert res.pi.shape[0] == 6                  # all rounds closed
    np.testing.assert_array_equal(res.pi.sum(1), np.full(6, 2))
    assert np.isfinite(res.total_time)


def test_degenerate_mobile_adapter_stays_bitwise_static():
    """Belt-and-braces on top of tests/test_mobility.py: the degenerate
    mobile configuration rides the same unified loop as the static path and
    must hit the same golden, bitwise on host math."""
    degen = dataclasses.replace(_cfg(), mobility=MobilityConfig(
        enabled=True, speed_mps=0.0, n_cells=1, hierarchy=False))
    res = run_simulation(degen, _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0)
    assert float(res.total_time).hex() == "0x1.4066315c4298cp+1"
    assert res.payload_dispatches == 8
    assert res.departed_arrivals == 0


# ---------------------------------------------------------------------------
# heterogeneous-resource knobs: degenerate configs stay bitwise identical
# ---------------------------------------------------------------------------

def test_explicit_uniform_budget_and_nearest_stay_bitwise_golden():
    """``association="nearest"`` + a uniform ``cell_bandwidth_hz`` equal to
    the system bandwidth are the explicit spellings of the defaults — the
    degenerate mobile config must still hit the PR-3 golden, bitwise."""
    degen = dataclasses.replace(_cfg(), mobility=MobilityConfig(
        enabled=True, speed_mps=0.0, n_cells=1, hierarchy=False,
        cell_bandwidth_hz=(1e6,), association="nearest"))
    res = run_simulation(degen, _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0)
    assert [float(t).hex() for t in res.times] == [
        "0x0.0p+0", "0x1.b877293c2d615p-1",
        "0x1.ae97a23acc733p+0", "0x1.4066315c4298cp+1"]
    assert float(res.total_time).hex() == "0x1.4066315c4298cp+1"
    assert res.payload_dispatches == 8


def test_multicell_uniform_budget_matches_unset_budget_bitwise():
    """A scalar-broadcast budget equal to the system bandwidth must be
    indistinguishable from the legacy unset spec on a REAL multi-cell
    hierarchy run (same trajectory, bitwise on host math)."""
    base = _mobile_cfg()
    explicit = dataclasses.replace(base, mobility=dataclasses.replace(
        base.mobility, cell_bandwidth_hz=(1e6,)))
    kw = dict(algorithm="perfed", mode="semi", bandwidth_policy="equal",
              max_rounds=6, eval_every=2, seed=0)
    r_a = run_simulation(base, _MODEL, _clients(), **kw)
    r_b = run_simulation(explicit, _MODEL, _clients(), **kw)
    np.testing.assert_array_equal(r_a.times, r_b.times)
    np.testing.assert_array_equal(r_a.losses, r_b.losses)
    np.testing.assert_array_equal(r_a.pi, r_b.pi)
    assert r_a.total_time == r_b.total_time


def test_one_cell_theorem2_matches_static_equal_finish_bitwise():
    """A 1-cell mobile drop under ``bandwidth_policy="theorem2"`` must
    price exactly the static path's ``equal_finish_allocation`` numbers:
    same distances/CPUs (the 1-cell drop is bitwise EdgeNetwork), same
    mean-fading channels, same bisection — so the allocation matches
    bit for bit."""
    from repro.core.bandwidth import equal_finish_allocation
    from repro.fl.mobile import MobileAdapter
    from repro.wireless.timing import compute_times

    n, seed = 8, 3
    cfg = dataclasses.replace(
        _cfg(n=n, eta_mode="distance"),        # geometric (non-uniform) drop
        mobility=MobilityConfig(enabled=True, model="static", speed_mps=0.0,
                                n_cells=1, hierarchy=False))
    adapter = MobileAdapter(cfg, n, seed=seed, bandwidth_policy="theorem2",
                            mode="semi")
    wl = cfg.wireless
    z_bits, d_i = 2.5e6, np.full(n, 24)
    adapter.bind_link_budget(z_bits, d_i)
    adapter.pre_requeue(np.arange(n))          # the driver's first pricing

    legacy = EdgeNetwork.drop(wl, n, seed=seed)
    h_mean = wl.rayleigh_scale * float(np.sqrt(np.pi / 2))
    chans = [legacy.channel(i, h_mean) for i in range(n)]
    tcmp = compute_times(wl.cpu_cycles_per_sample, d_i, legacy.cpu_freq)
    want = equal_finish_allocation(np.full(n, z_bits), tcmp, chans,
                                   wl.total_bandwidth_hz)
    assert want.converged
    np.testing.assert_array_equal(adapter.bw, want.b)
    assert float(adapter._t_star[0]) == want.t_star
