"""Theorems 2–4: Lambert-W, rate inversion, equal-finish optimality."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.core.bandwidth import (UEChannel, bandwidth_for_rate,
                                  bandwidth_for_time, equal_finish_allocation,
                                  lambertw, theorem4_lower_bound, uplink_rate,
                                  weighted_equal_rate_allocation)

N0 = 10 ** (-174.0 / 10.0) / 1000.0


def _ch(h=40.0, d=100.0):
    return UEChannel(p=0.01, h=h, dist=d, kappa=3.8, n0=N0)


@given(st.floats(1e-3, 50.0))
@settings(max_examples=100, deadline=None)
def test_lambertw_principal_inverse(x):
    w = float(lambertw(x * np.exp(x), branch=0))
    assert abs(w - x) < 1e-6 * max(1.0, x)


@given(st.floats(-60.0, -1.0001))
@settings(max_examples=100, deadline=None)
def test_lambertw_minus1_inverse(x):
    w = float(lambertw(x * np.exp(x), branch=-1))
    assert abs(w - x) < 1e-5 * max(1.0, abs(x))


@given(st.floats(1e3, 1e6), st.floats(1.0, 200.0), st.floats(10.0, 200.0))
@settings(max_examples=60, deadline=None)
def test_rate_monotone_in_bandwidth(b, h, d):
    """Theorem 2's premise: r(b) strictly increasing (Eq. 31)."""
    ch = _ch(h, d)
    assert uplink_rate(b * 1.01, ch) > uplink_rate(b, ch)


@given(st.floats(1e3, 5e5), st.floats(5.0, 200.0), st.floats(10.0, 200.0))
@settings(max_examples=60, deadline=None)
def test_bandwidth_for_rate_inverts_rate(b, h, d):
    ch = _ch(h, d)
    r = float(uplink_rate(b, ch))
    b2 = bandwidth_for_rate(r, ch)
    assert abs(b2 - b) / b < 1e-5


def test_equal_finish_times_theorem2():
    """All scheduled UEs finish at the same instant under the optimum."""
    z = [4e5, 4e5, 4e5]
    tc = [0.05, 0.15, 0.30]
    chans = [_ch(40, 50), _ch(25, 120), _ch(15, 180)]
    b, t_star, converged = equal_finish_allocation(z, tc, chans, 1e6)
    assert converged
    assert abs(b.sum() - 1e6) / 1e6 < 1e-6
    finish = [tc[i] + z[i] * np.log(2) / uplink_rate(b[i], chans[i])
              for i in range(3)]
    assert np.ptp(finish) < 1e-3 * t_star
    assert abs(np.mean(finish) - t_star) < 1e-2 * t_star


def test_equal_finish_beats_equal_split():
    """Theorem-2 allocation ≤ round time of the naive equal split."""
    z = [4e5] * 3
    tc = [0.05, 0.1, 0.2]
    chans = [_ch(40, 50), _ch(25, 120), _ch(15, 180)]
    _, t_opt, _ = equal_finish_allocation(z, tc, chans, 1e6)
    b_eq = 1e6 / 3
    t_eq = max(tc[i] + z[i] * np.log(2) / uplink_rate(b_eq, chans[i])
               for i in range(3))
    assert t_opt <= t_eq * (1 + 1e-9)


def test_bandwidth_for_time_consistency():
    ch = _ch()
    z, tcmp, t = 4e5, 0.1, 0.5
    b = bandwidth_for_time(z, t, tcmp, ch)
    # uploading z bits at rate r(b) should take exactly t − tcmp
    t_up = z * np.log(2) / uplink_rate(b, ch)
    assert abs(t_up - (t - tcmp)) / (t - tcmp) < 1e-6


def test_weighted_equal_rate_allocation():
    """The 'other extreme' of Theorem 4: r_i/η_i equalised, Σb = B."""
    eta = np.array([0.5, 0.3, 0.2])
    chans = [_ch(40, 50), _ch(25, 120), _ch(15, 180)]
    b = weighted_equal_rate_allocation(eta, chans, 1e6)
    assert abs(b.sum() - 1e6) / 1e6 < 1e-6
    r = np.array([float(uplink_rate(b[i], chans[i])) for i in range(3)])
    ratios = r / eta
    assert np.ptp(ratios) / ratios.mean() < 1e-2


def test_infeasible_time_returns_inf():
    ch = _ch()
    assert bandwidth_for_time(1e6, 0.05, 0.1, ch) == float("inf")


def test_equal_finish_surfaces_nonconvergence():
    """max_iter too small → the silent simplex rescale used to hide that
    the returned b no longer equalises finish times; now converged=False."""
    z = [4e5, 4e5, 4e5]
    tc = [0.05, 0.15, 0.30]
    chans = [_ch(40, 50), _ch(25, 120), _ch(15, 180)]
    res = equal_finish_allocation(z, tc, chans, 1e6, max_iter=1)
    assert not res.converged
    assert abs(res.b.sum() - 1e6) / 1e6 < 1e-6    # still on the simplex
    ok = equal_finish_allocation(z, tc, chans, 1e6)
    assert ok.converged


@given(st.floats(0.2, 0.9), st.floats(5.0, 150.0), st.floats(20.0, 180.0),
       st.floats(0.05, 1.0))
@settings(max_examples=60, deadline=None)
def test_theorem4_lower_bound_matches_gamma_closed_form(t, h, d, eta_i):
    """The simplified Γ form is η_i · b(Z/t_com) with b the Theorem-4
    closed-form bandwidth (``bandwidth_for_rate``); the old version
    multiplied *and divided* by total_bw·n_ues around the same quantity."""
    ch = _ch(h, d)
    z, tcmp = 4e5, 0.05
    t_com = t - tcmp
    want_b = bandwidth_for_rate(z / t_com, ch)
    got = theorem4_lower_bound(z, t, tcmp, ch, eta_i)
    if not np.isfinite(want_b):
        assert got == float("inf")
    else:
        assert abs(got - eta_i * want_b) <= 1e-9 * max(abs(got), 1.0)


def test_theorem4_lower_bound_infeasible():
    assert theorem4_lower_bound(4e5, 0.05, 0.1, _ch(), 0.5) == float("inf")
