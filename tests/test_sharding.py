"""Logical-axis rules → PartitionSpec resolution."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    # single host device → (1, 1) mesh with production axis names
    return make_mesh((1, 1), ("data", "model"))


def test_logical_spec_basic(mesh):
    spec = sharding.logical_spec(("batch", None, "heads"), mesh)
    assert spec == P("data", None, "model")


def test_logical_spec_no_double_axis_use(mesh):
    # two dims mapping to "model": the second must resolve to None
    spec = sharding.logical_spec(("experts", "embed", "ffn"), mesh)
    assert spec == P("model", "data", None)


def test_logical_spec_without_mesh():
    spec = sharding.logical_spec(("batch", "heads"), None)
    assert spec == P(None, None)


def test_param_specs_cover_model(mesh):
    cfg = get_config("yi_6b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.param_specs(params, mesh)
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat) == len(jax.tree.leaves(params))
    # attention weights must be model-sharded on their feature dim
    d = {sharding._path_str(p): s for p, s in flat}
    wq = [v for k, v in d.items() if "w_q" in k][0]
    assert "model" in jax.tree.leaves(wq) or "model" in tuple(wq)


def test_moe_param_specs(mesh):
    cfg = get_config("deepseek_v2_236b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.param_specs(params, mesh)
    flat = {sharding._path_str(p): s for p, s in
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, P))}
    gate = [v for k, v in flat.items() if "moe_gate" in k][0]
    assert gate[1] == "model"        # (layers, experts→model, embed→data, ffn)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "batch", None) is x


def test_rules_overrides():
    rules = sharding.AxisRules().with_overrides(experts=())
    mesh = make_mesh((1, 1), ("data", "model"))
    spec = sharding.logical_spec(("experts", "embed", "ffn"), mesh, rules)
    assert spec == P(None, "data", "model")
