"""Mobility subsystem: models, multi-cell network, hierarchy, sim parity."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          WirelessConfig)
from repro.configs import get_config
from repro.core.hierarchy import (NON_MEMBER, HierarchicalServer,
                                  HierarchyConfig)
from repro.core.server import ServerConfig
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.mobility.models import (Area, GaussMarkov, RandomWaypoint,
                                   StaticMobility, get_mobility)
from repro.mobility.multicell import (MIN_DIST_M, MultiCellNetwork,
                                      _associate, _associate_load_aware,
                                      cell_layout, resolve_cell_bandwidth)
from repro.models import build_model
from repro.wireless.channel import EdgeNetwork

AREA = Area(0.0, 0.0, 400.0, 400.0)


# ---------------------------------------------------------------------------
# mobility models
# ---------------------------------------------------------------------------

def _roll(model, n=64, steps=50, dt=1.0, seed=0):
    rng = np.random.default_rng(seed)
    pos = AREA.uniform(rng, n)
    state = model.init_state(n, AREA, rng)
    traj = [pos]
    for _ in range(steps):
        pos, state = model.step(pos, state, dt, AREA, rng)
        traj.append(pos)
    return np.stack(traj)


def test_static_mobility_never_moves():
    traj = _roll(StaticMobility())
    assert np.array_equal(traj[0], traj[-1])


@pytest.mark.parametrize("model", [RandomWaypoint(speed_mps=10.0),
                                   GaussMarkov(speed_mps=10.0)])
def test_models_move_and_stay_in_area(model):
    traj = _roll(model)
    assert not np.allclose(traj[0], traj[-1])
    assert AREA.contains(traj.reshape(-1, 2)).all()


def test_random_waypoint_respects_speed_bound():
    model = RandomWaypoint(speed_mps=10.0)
    traj = _roll(model, dt=1.0)
    step_len = np.linalg.norm(np.diff(traj, axis=0), axis=-1)
    # per-leg speed is U[0.5, 1.5]·v̄
    assert step_len.max() <= 1.5 * 10.0 + 1e-9


def test_mobility_deterministic_per_seed():
    a = _roll(RandomWaypoint(speed_mps=5.0), seed=7)
    b = _roll(RandomWaypoint(speed_mps=5.0), seed=7)
    c = _roll(RandomWaypoint(speed_mps=5.0), seed=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_get_mobility_factory():
    assert isinstance(get_mobility("random_waypoint", speed_mps=0.0),
                      StaticMobility)
    assert isinstance(get_mobility("random_waypoint", speed_mps=2.0),
                      RandomWaypoint)
    assert isinstance(get_mobility("gauss_markov", speed_mps=2.0),
                      GaussMarkov)
    with pytest.raises(ValueError):
        get_mobility("teleport", speed_mps=2.0)


# ---------------------------------------------------------------------------
# multi-cell network
# ---------------------------------------------------------------------------

def test_cell_layout_distinct_positions():
    xy = cell_layout(7, 200.0)
    assert xy.shape == (7, 2)
    d = np.linalg.norm(xy[:, None] - xy[None, :], axis=-1)
    assert (d[~np.eye(7, dtype=bool)] > 200.0).all()


def test_single_cell_drop_matches_edge_network_bitwise():
    """The 1-cell static drop consumes the main RNG stream exactly as
    EdgeNetwork.drop — distances, CPU freqs, and the fading stream must be
    bitwise identical."""
    cfg = WirelessConfig()
    legacy = EdgeNetwork.drop(cfg, 16, seed=3)
    net = MultiCellNetwork.drop(cfg, 16, n_cells=1, seed=3)
    np.testing.assert_array_equal(legacy.distances, net.distances)
    np.testing.assert_array_equal(legacy.cpu_freq, net.cpu_freq)
    np.testing.assert_array_equal(legacy.sample_fading(), net.sample_fading())


def test_nearest_bs_association():
    net = MultiCellNetwork.drop(WirelessConfig(), 64, n_cells=4, seed=0)
    d = np.linalg.norm(net.positions[:, None] - net.bs_xy[None], axis=-1)
    np.testing.assert_array_equal(net.assoc, d.argmin(1))
    assert net.cell_counts().sum() == 64


def test_advance_counts_handovers_and_moves_ues():
    net = MultiCellNetwork.drop(WirelessConfig(), 128, n_cells=4, seed=0,
                                mobility="random_waypoint", speed_mps=50.0)
    p0 = net.positions.copy()
    events = []
    for t in range(1, 31):
        events += net.advance_to(float(t * 10))
    assert not np.allclose(p0, net.positions)
    assert net.handovers == len(events) and net.handovers >= 1
    for (ue, src, dst) in events:
        assert src != dst and 0 <= ue < 128
    # association stays nearest-BS after movement
    d = np.linalg.norm(net.positions[:, None] - net.bs_xy[None], axis=-1)
    np.testing.assert_array_equal(net.assoc, d.argmin(1))


def test_static_advance_is_pure_clock_update():
    net = MultiCellNetwork.drop(WirelessConfig(), 16, n_cells=2, seed=0)
    d0, a0 = net.distances.copy(), net.assoc.copy()
    assert net.advance_to(1e6) == []
    np.testing.assert_array_equal(net.distances, d0)
    np.testing.assert_array_equal(net.assoc, a0)
    assert net.time == 1e6


# ---------------------------------------------------------------------------
# heterogeneous per-cell radio resources: budgets + association policies
# ---------------------------------------------------------------------------

def test_resolve_cell_bandwidth_broadcast_and_validation():
    np.testing.assert_array_equal(resolve_cell_bandwidth((), 3, 1e6),
                                  [1e6, 1e6, 1e6])
    np.testing.assert_array_equal(resolve_cell_bandwidth(None, 2, 5e5),
                                  [5e5, 5e5])
    np.testing.assert_array_equal(resolve_cell_bandwidth((2e6,), 3, 1e6),
                                  [2e6, 2e6, 2e6])
    np.testing.assert_array_equal(
        resolve_cell_bandwidth((2e6, 5e5, 5e5), 3, 1e6), [2e6, 5e5, 5e5])
    with pytest.raises(ValueError, match="2 entries for 3 cells"):
        resolve_cell_bandwidth((1e6, 2e6), 3, 1e6)
    with pytest.raises(ValueError, match="positive"):
        resolve_cell_bandwidth((1e6, 0.0), 2, 1e6)


def test_cell_bandwidth_override_coerces_to_floats():
    from repro.config import ExperimentConfig, apply_overrides
    cfg = apply_overrides(ExperimentConfig(),
                          {"mobility.cell_bandwidth_hz": "2e6, 5e5"})
    assert cfg.mobility.cell_bandwidth_hz == (2e6, 5e5)
    cleared = apply_overrides(cfg, {"mobility.cell_bandwidth_hz": ""})
    assert cleared.mobility.cell_bandwidth_hz == ()


def test_unknown_association_policy_rejected():
    with pytest.raises(ValueError, match="association"):
        MultiCellNetwork.drop(WirelessConfig(), 8, n_cells=2,
                              association="teleport")


def test_load_aware_sheds_ues_from_hot_cell():
    """A cluster just on cell 0's side of the midline: nearest piles all of
    them onto BS 0; load-aware spills the marginal ones to BS 1 once the
    load penalty outweighs the small distance gap."""
    bs = np.array([[0.0, 0.0], [100.0, 0.0]])
    pos = np.stack([np.linspace(38.0, 49.0, 10), np.zeros(10)], axis=1)
    a_near, d_near = _associate(pos, bs)
    assert (a_near == 0).all()
    bw = np.array([1e6, 1e6])
    a_load, d_load = _associate_load_aware(pos, bs, bw, penalty_m=50.0)
    counts = np.bincount(a_load, minlength=2)
    assert counts[1] >= 1                     # hot cell shed at least one
    assert counts.max() < 10                  # strictly more balanced
    # serving distance stays the TRUE distance to the serving BS
    d = np.linalg.norm(pos[:, None] - bs[None], axis=-1)
    np.testing.assert_array_equal(
        d_load, np.maximum(d[np.arange(10), a_load], MIN_DIST_M))


def test_load_aware_fair_share_scales_with_budget():
    """With a macro budget on BS 0, its fair share grows — the same drop
    keeps more UEs on the macro cell than under equal budgets."""
    bs = np.array([[0.0, 0.0], [100.0, 0.0]])
    rng = np.random.default_rng(0)
    pos = np.stack([rng.uniform(20.0, 80.0, 40),
                    rng.uniform(-30.0, 30.0, 40)], axis=1)
    a_eq, _ = _associate_load_aware(pos, bs, np.array([1e6, 1e6]),
                                    penalty_m=50.0)
    a_macro, _ = _associate_load_aware(pos, bs, np.array([4e6, 1e6]),
                                       penalty_m=50.0)
    assert np.bincount(a_macro, minlength=2)[0] > \
        np.bincount(a_eq, minlength=2)[0]


def test_load_aware_deterministic_and_stable_on_balanced_input():
    net_a = MultiCellNetwork.drop(WirelessConfig(), 64, n_cells=4, seed=2,
                                  association="load_aware")
    net_b = MultiCellNetwork.drop(WirelessConfig(), 64, n_cells=4, seed=2,
                                  association="load_aware")
    np.testing.assert_array_equal(net_a.assoc, net_b.assoc)
    np.testing.assert_array_equal(net_a.distances, net_b.distances)
    assert net_a.cell_counts().sum() == 64


def test_load_aware_advance_emits_consistent_handover_events():
    net = MultiCellNetwork.drop(WirelessConfig(), 64, n_cells=3, seed=1,
                                mobility="random_waypoint", speed_mps=40.0,
                                association="load_aware",
                                cell_bandwidth_hz=(2e6, 5e5, 5e5))
    events = []
    for t in range(1, 21):
        events += net.advance_to(float(t * 10))
    assert net.handovers == len(events)
    for (ue, src, dst) in events:
        assert src != dst and 0 <= ue < 64
    # distances always the true serving-BS distance
    d = np.linalg.norm(net.positions[:, None] - net.bs_xy[None], axis=-1)
    np.testing.assert_array_equal(
        net.distances,
        np.maximum(d[np.arange(64), net.assoc], MIN_DIST_M))


def test_nearest_with_budgets_keeps_legacy_association():
    """Budgets alone must not perturb the nearest-BS association or the
    fading stream: geometry is untouched by ``cell_bandwidth_hz``."""
    a = MultiCellNetwork.drop(WirelessConfig(), 32, n_cells=4, seed=5)
    b = MultiCellNetwork.drop(WirelessConfig(), 32, n_cells=4, seed=5,
                              cell_bandwidth_hz=(2e6, 5e5, 5e5, 1e6))
    np.testing.assert_array_equal(a.assoc, b.assoc)
    np.testing.assert_array_equal(a.distances, b.distances)
    np.testing.assert_array_equal(a.sample_fading(), b.sample_fading())
    np.testing.assert_array_equal(b.cell_bw, [2e6, 5e5, 5e5, 1e6])


# ---------------------------------------------------------------------------
# hierarchical cell → cloud aggregation
# ---------------------------------------------------------------------------

def _hier(n=8, n_cells=2, a=1, every=2):
    params = {"w": jnp.arange(4.0)}
    cfgs = [ServerConfig(n_ues=n, participants_per_round=a,
                         staleness_bound=3, beta=0.1) for _ in range(n_cells)]
    members = [np.arange(n // 2), np.arange(n // 2, n)]
    return HierarchicalServer(params, cfgs,
                              HierarchyConfig(n_cells=n_cells,
                                              cloud_sync_every=every),
                              members)


def test_cloud_merge_is_weighted_mean():
    h = _hier()
    h.cells[0].params = {"w": jnp.full(4, 1.0)}
    h.cells[1].params = {"w": jnp.full(4, 4.0)}
    h._arrivals_since_sync[:] = [3, 1]
    h.cloud_sync()
    np.testing.assert_allclose(np.asarray(h.cloud_params["w"]),
                               (3 * 1.0 + 1 * 4.0) / 4.0, rtol=1e-6)
    for srv in h.cells:
        np.testing.assert_allclose(np.asarray(srv.params["w"]),
                                   np.asarray(h.cloud_params["w"]))
    assert h.cloud_rounds == 1 and h._arrivals_since_sync.sum() == 0


def test_rounds_and_cloud_cadence():
    h = _hier(every=2)
    grad = {"w": jnp.ones(4)}
    r1 = h.on_arrival(0, 0, grad)
    assert r1 is not None and r1["round"] == 1 and not r1["cloud_synced"]
    r2 = h.on_arrival(1, 5, grad)
    assert r2["round"] == 2 and r2["cloud_synced"]
    assert h.cloud_rounds == 1 and h.edge_rounds == 2
    assert h.pi_matrix().shape == (2, 8)


def test_handover_carries_staleness():
    h = _hier(every=0)
    grad = {"w": jnp.ones(4)}
    # cell 1 completes 4 rounds; UE 0 (cell 0) never participates
    for _ in range(4):
        h.on_arrival(1, 5, grad)
    assert h.cells[0].staleness(0) == 0
    h.handover(0, 0, 1)
    assert h.cells[0].ue_version[0] == NON_MEMBER
    # fresh in its old cell ⇒ fresh in the new cell's clock
    assert h.cells[1].staleness(0) == 0
    # a stale UE keeps its staleness across the boundary
    h.cells[1].ue_version[6] = 1          # τ = 4 − 1 = 3 in cell 1
    h.handover(6, 1, 0)
    assert h.cells[0].staleness(6) == 3


def test_arrival_after_handover_does_not_resurrect_membership():
    """A UE whose upload is pending at cell 0 when it hands over to cell 1
    must not be re-adopted (or pushed to) by cell 0 when its round closes."""
    h = _hier(a=2, every=0)
    grad = {"w": jnp.ones(4)}
    assert h.on_arrival(0, 1, grad) is None       # pending in cell 0
    h.handover(1, 0, 1)                            # leaves mid-flight
    res = h.on_arrival(0, 2, grad)                 # closes cell 0's round
    assert res is not None
    assert 1 not in res["distribute"]
    assert h.cells[0].ue_version[1] == NON_MEMBER
    assert h.member_cell[1] == 1


def test_late_delivery_from_departed_ue_has_sane_staleness():
    """An upload delivered to the old cell *after* the handover bookkeeping
    ran must get a finite staleness (λ^τ weighting would overflow on the
    sentinel) and leave membership untouched."""
    params = {"w": jnp.zeros(4)}
    cfgs = [ServerConfig(n_ues=8, participants_per_round=1,
                         staleness_bound=3, beta=0.1,
                         staleness_discount=0.5) for _ in range(2)]
    h = HierarchicalServer(params, cfgs,
                           HierarchyConfig(n_cells=2, cloud_sync_every=0),
                           [np.arange(4), np.arange(4, 8)])
    h.handover(1, 0, 1)
    res = h.on_arrival(0, 1, {"w": jnp.ones(4)})   # late delivery to cell 0
    assert res is not None and 1 not in res["distribute"]
    assert h.cells[0].ue_version[1] == NON_MEMBER
    tau = h.cells[0].history_staleness[-1]
    assert np.isfinite(np.asarray(res["params"]["w"])).all()
    assert abs(int(tau[1])) < 100                  # sane, not ±2^60


def test_non_members_never_force_refreshed():
    h = _hier(every=0)
    grad = {"w": jnp.ones(4)}
    for _ in range(6):                     # staleness bound is 3
        res = h.on_arrival(1, 5, grad)
    # distribute never includes cell-0 members (sentinel version)
    assert all(i >= 4 for i in res["distribute"])


# ---------------------------------------------------------------------------
# simulation parity + mobile runs
# ---------------------------------------------------------------------------

_DATA = synthetic_mnist(n=1200, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _cfg(n=8, a=3, s=3, **fl_kw):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8, **fl_kw))


def _clients(n=8, seed=0):
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


def test_degenerate_mobile_is_bitwise_identical_to_static():
    """speed 0, one cell, hierarchy off ⇒ the mobile driver reproduces the
    legacy single-cell trajectory bitwise (same seed)."""
    base = _cfg()
    kw = dict(algorithm="perfed", mode="semi", max_rounds=6, eval_every=2,
              seed=0)
    r_static = run_simulation(base, _MODEL, _clients(), **kw)
    degen = dataclasses.replace(base, mobility=MobilityConfig(
        enabled=True, speed_mps=0.0, n_cells=1, hierarchy=False))
    r_mob = run_simulation(degen, _MODEL, _clients(), **kw)
    np.testing.assert_array_equal(r_static.losses, r_mob.losses)
    np.testing.assert_array_equal(r_static.global_losses, r_mob.global_losses)
    np.testing.assert_array_equal(r_static.times, r_mob.times)
    np.testing.assert_array_equal(r_static.pi, r_mob.pi)
    assert r_mob.handovers == 0 and r_mob.cloud_rounds == 0
    assert r_mob.payload_dispatches == r_static.payload_dispatches


def test_degenerate_equal_bandwidth_and_eta_modes_match_too():
    base = _cfg(n=6, a=2, s=2)
    base = dataclasses.replace(
        base, fl=dataclasses.replace(base.fl, eta_mode="distance"))
    kw = dict(algorithm="fedavg", mode="semi", max_rounds=4, eval_every=2,
              seed=4, bandwidth_policy="equal")
    r_static = run_simulation(base, _MODEL, _clients(6, seed=4), **kw)
    degen = dataclasses.replace(base, mobility=MobilityConfig(
        enabled=True, speed_mps=0.0, n_cells=1))
    r_mob = run_simulation(degen, _MODEL, _clients(6, seed=4), **kw)
    np.testing.assert_array_equal(r_static.losses, r_mob.losses)
    np.testing.assert_array_equal(r_static.times, r_mob.times)


def test_mobile_multicell_hierarchy_run():
    n = 24
    cfg = dataclasses.replace(
        _cfg(n=n, a=6, s=4, first_order=True),
        mobility=MobilityConfig(enabled=True, model="random_waypoint",
                                speed_mps=40.0, n_cells=3, hierarchy=True,
                                cloud_sync_every=3))
    res = run_simulation(cfg, _MODEL, _clients(n), algorithm="perfed",
                         mode="semi", bandwidth_policy="equal",
                         max_rounds=9, eval_every=3, seed=0)
    assert res.n_cells == 3
    assert res.rounds[-1] == 9
    assert res.cloud_rounds == 3          # every 3 of 9 edge rounds
    assert res.pi.shape[0] == 9
    assert np.isfinite(res.losses).all()
    assert res.total_time > 0


def test_mobile_multicell_flat_server_run():
    """Multi-cell without hierarchy: one global server, per-cell bandwidth."""
    n = 16
    cfg = dataclasses.replace(
        _cfg(n=n, a=4, s=3, first_order=True),
        mobility=MobilityConfig(enabled=True, model="gauss_markov",
                                speed_mps=30.0, n_cells=4, hierarchy=False))
    res = run_simulation(cfg, _MODEL, _clients(n), algorithm="perfed",
                         mode="semi", bandwidth_policy="equal",
                         max_rounds=5, eval_every=0, seed=2)
    assert res.n_cells == 4 and res.cloud_rounds == 0
    assert res.pi.shape[0] == 5


def test_mobile_same_seed_reproducible():
    n = 16
    cfg = dataclasses.replace(
        _cfg(n=n, a=4, s=3, first_order=True),
        mobility=MobilityConfig(enabled=True, speed_mps=25.0, n_cells=2,
                                hierarchy=True, cloud_sync_every=2))
    kw = dict(algorithm="perfed", mode="semi", bandwidth_policy="equal",
              max_rounds=6, eval_every=3, seed=5)
    a = run_simulation(cfg, _MODEL, _clients(n, seed=5), **kw)
    b = run_simulation(cfg, _MODEL, _clients(n, seed=5), **kw)
    np.testing.assert_array_equal(a.losses, b.losses)
    np.testing.assert_array_equal(a.pi, b.pi)
    assert a.handovers == b.handovers
