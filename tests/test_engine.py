"""SimulationEngine: batched == sequential, bucket padding, unified Eq. (8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.engine import SimulationEngine, bucket_size
from repro.fl.simulation import run_simulation
from repro.kernels.stale_aggregate import (masked_aggregate_tree,
                                           stale_aggregate_tree)
from repro.models import build_model
from repro.utils.tree import TreeFlattener

_DATA = synthetic_mnist(n=600, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _cfg(n=8, a=3, s=3):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8))


def _clients(n=8, seed=0):
    # fresh per run: each ClientDataset owns a stateful np generator, so
    # equivalence runs must not share sampler state
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


# ---------------------------------------------------------------------------
# batched vs sequential equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,algorithm", [("semi", "perfed"),
                                            ("semi", "fedavg"),
                                            ("sync", "perfed"),
                                            ("async", "perfed")])
def test_batched_reproduces_sequential(mode, algorithm):
    cfg = _cfg()
    kw = dict(algorithm=algorithm, mode=mode, max_rounds=6, eval_every=2,
              seed=0)
    r_seq = run_simulation(cfg, _MODEL, _clients(), payload_mode="sequential",
                           **kw)
    r_bat = run_simulation(cfg, _MODEL, _clients(), payload_mode="batched",
                           **kw)
    np.testing.assert_array_equal(r_seq.pi, r_bat.pi)
    np.testing.assert_allclose(r_seq.losses, r_bat.losses, rtol=1e-5)
    np.testing.assert_allclose(r_seq.times, r_bat.times)
    assert r_bat.payloads_computed == r_seq.payloads_computed
    # the whole point: far fewer device dispatches on the batched path
    if mode != "async":
        assert r_bat.payload_dispatches < r_seq.payload_dispatches


def test_same_seed_is_reproducible():
    cfg = _cfg()
    kw = dict(algorithm="perfed", mode="semi", max_rounds=5, eval_every=2,
              seed=3, payload_mode="batched")
    a = run_simulation(cfg, _MODEL, _clients(), **kw)
    b = run_simulation(cfg, _MODEL, _clients(), **kw)
    np.testing.assert_array_equal(a.losses, b.losses)
    np.testing.assert_array_equal(a.pi, b.pi)


# ---------------------------------------------------------------------------
# bucket padding
# ---------------------------------------------------------------------------

def test_bucket_size_powers_of_two():
    assert [bucket_size(m) for m in (1, 2, 3, 4, 5, 9, 17)] == \
        [1, 2, 4, 4, 8, 16, 32]
    assert bucket_size(300, max_bucket=256) == 256
    with pytest.raises(ValueError):
        bucket_size(0)


@pytest.mark.parametrize("m", [1, 3, 5, 7])
def test_padded_bucket_matches_per_item(m):
    """Non-power-of-2 batch sizes: padded lanes must not leak into results."""
    fl = _cfg().fl
    clients = _clients()
    params = _MODEL.init(jax.random.PRNGKey(1))
    eng_b = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    eng_s = SimulationEngine(_MODEL, fl, "perfed", payload_mode="sequential")

    batches = [clients[i % len(clients)].sample_triplet(8, 8, 8)
               for i in range(m)]
    rngs = [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(m)]
    alphas = [0.03 + 0.01 * i for i in range(m)]
    got = eng_b.compute_payloads([params] * m, batches, rngs, alphas)
    want = eng_s.compute_payloads([params] * m, batches, rngs, alphas)
    assert eng_b.dispatches == 1 and eng_s.dispatches == m
    for g, w in zip(got, want):
        for gl, wl in zip(jax.tree.leaves(g), jax.tree.leaves(w)):
            np.testing.assert_allclose(np.asarray(gl), np.asarray(wl),
                                       rtol=1e-6, atol=1e-7)


def test_heterogeneous_shapes_grouped():
    """Arrivals whose shard is smaller than the batch size (shape stragglers)
    must land in their own bucket, not crash the vmap."""
    fl = _cfg().fl
    clients = _clients()
    params = _MODEL.init(jax.random.PRNGKey(1))
    eng = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    big = [clients[i].sample_triplet(8, 8, 8) for i in range(3)]
    small = [clients[0].sample_triplet(2, 2, 2)]
    batches = big + small
    rngs = [jax.random.PRNGKey(i) for i in range(4)]
    out = eng.compute_payloads([params] * 4, batches, rngs, [0.03] * 4)
    assert len(out) == 4 and all(o is not None for o in out)
    assert eng.dispatches == 2        # one per shape signature


# ---------------------------------------------------------------------------
# unified aggregation API vs tree_map reference
# ---------------------------------------------------------------------------

def _tree_map_reference(params, payloads, mask, beta):
    """The hand-rolled reduction the server used to do."""
    agg = None
    for g, w in zip(payloads, np.asarray(mask)):
        scaled = jax.tree.map(lambda x: float(w) * x, g)
        agg = scaled if agg is None else jax.tree.map(jnp.add, agg, scaled)
    a = max(float(np.asarray(mask).sum()), 1.0)
    return jax.tree.map(lambda g, p: p - beta / a * g, agg, params)


def test_stale_aggregate_tree_matches_tree_map_reference(rng):
    """On a real model pytree (nested dicts, mixed shapes)."""
    params = _MODEL.init(rng)
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    payloads = [jax.tree.map(
        lambda p, k=k: jax.random.normal(k, p.shape, p.dtype), params)
        for k in keys]
    mask = jnp.array([1.0, 0.0, 2.5, 1.0])
    got = stale_aggregate_tree(params, payloads, mask, beta=0.07)
    want = _tree_map_reference(params, payloads, mask, 0.07)
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(params)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_stale_aggregate_tree_stacked_and_pallas_agree(rng):
    params = _MODEL.init(rng)
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    payloads = [jax.tree.map(
        lambda p, k=k: jax.random.normal(k, p.shape, p.dtype), params)
        for k in keys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
    mask = jnp.array([1.0, 1.0, 0.0])
    a = stale_aggregate_tree(params, payloads, mask, beta=0.1, backend="jnp")
    b = stale_aggregate_tree(params, stacked, mask, beta=0.1, backend="jnp")
    c = stale_aggregate_tree(params, stacked, mask, beta=0.1,
                             backend="pallas")
    for x, y, z in zip(jax.tree.leaves(a), jax.tree.leaves(b),
                       jax.tree.leaves(c)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(x), np.asarray(z), rtol=1e-5,
                                   atol=1e-6)


def test_masked_aggregate_tree_is_masked_mean(rng):
    params = _MODEL.init(rng)
    stacked = jax.tree.map(
        lambda p: jnp.stack([jnp.full(p.shape, float(i + 1), jnp.float32)
                             for i in range(3)]), params)
    agg = masked_aggregate_tree(stacked, jnp.array([1.0, 0.0, 1.0]))
    for leaf in jax.tree.leaves(agg):
        np.testing.assert_allclose(np.asarray(leaf), (1.0 + 3.0) / 2.0,
                                   rtol=1e-6)


def test_tree_flattener_roundtrip(rng):
    params = _MODEL.init(rng)
    flat = TreeFlattener.for_tree(params)
    assert flat is TreeFlattener.for_tree(params)      # cached by structure
    vec = flat.flatten(params)
    assert vec.ndim == 1 and vec.shape[0] == flat.size
    back = flat.unflatten(vec)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# stacked payloads (batch-wise protocol feed)
# ---------------------------------------------------------------------------

def _group_stack(batches, lanes):
    return jax.tree.map(lambda *xs: np.stack(xs), *[batches[i] for i in lanes])


def test_compute_payloads_stacked_matches_per_lane():
    """Interleaved shape groups: the stacked entry must return the rows in
    arrival order (inverse permute across groups) and match the per-lane
    path lane for lane."""
    fl = _cfg().fl
    clients = _clients()
    params = _MODEL.init(jax.random.PRNGKey(1))
    eng_a = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    eng_b = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    key = jax.random.PRNGKey(7)
    big = [clients[i].sample_triplet(8, 8, 8) for i in range(3)]
    small = [clients[0].sample_triplet(2, 2, 2) for _ in range(2)]
    # arrival order interleaves the two signatures
    batches = [big[0], small[0], big[1], small[1], big[2]]
    seqs = [10, 11, 12, 13, 14]
    alphas = [0.03 + 0.01 * i for i in range(5)]
    groups = [([1, 3], _group_stack(batches, [1, 3])),
              ([0, 2, 4], _group_stack(batches, [0, 2, 4]))]
    stacked = eng_a.compute_payloads_stacked([params] * 5, groups, seqs,
                                             alphas, key)
    want = eng_b.compute_payloads([params] * 5, batches,
                                  [jax.random.fold_in(key, s) for s in seqs],
                                  alphas)
    assert eng_a.dispatches == eng_b.dispatches == 2
    assert eng_a.payloads_computed == 5
    for lane in range(5):
        row = jax.tree.map(lambda x, lane=lane: x[lane], stacked)
        for rl, wl in zip(jax.tree.leaves(row), jax.tree.leaves(want[lane])):
            np.testing.assert_allclose(np.asarray(rl), np.asarray(wl),
                                       rtol=1e-6, atol=1e-7)


def test_compute_payloads_stacked_singleton_rides_single_jit():
    """A 1-lane group must ride the exact scalar ``_single`` jit bitwise —
    no bucket padding, no vmap."""
    fl = _cfg().fl
    clients = _clients()
    params = _MODEL.init(jax.random.PRNGKey(1))
    eng = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    key = jax.random.PRNGKey(7)
    batch = clients[0].sample_triplet(8, 8, 8)
    stacked = eng.compute_payloads_stacked(
        [params], [([0], _group_stack([batch], [0]))], [5], [0.03], key)
    assert eng.dispatches == 1
    want = eng._single(params, batch, jax.random.fold_in(key, 5), 0.03)
    for sl, wl in zip(jax.tree.leaves(stacked), jax.tree.leaves(want)):
        assert sl.shape[0] == 1
        np.testing.assert_array_equal(np.asarray(sl[0]), np.asarray(wl))


def test_singleton_group_rides_single_jit_in_per_lane_path():
    """``compute_payloads``'s singleton shape group must also skip bucket
    padding and match ``_single`` bitwise."""
    fl = _cfg().fl
    clients = _clients()
    params = _MODEL.init(jax.random.PRNGKey(1))
    eng = SimulationEngine(_MODEL, fl, "perfed", payload_mode="batched")
    big = [clients[i].sample_triplet(8, 8, 8) for i in range(2)]
    small = clients[0].sample_triplet(2, 2, 2)
    rngs = [jax.random.PRNGKey(i) for i in range(3)]
    out = eng.compute_payloads([params] * 3, big + [small], rngs,
                               [0.03] * 3)
    assert eng.dispatches == 2            # one vmap bucket + one _single
    want = eng._single(params, small, rngs[2], 0.03)
    for ol, wl in zip(jax.tree.leaves(out[2]), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(ol), np.asarray(wl))
