"""Cited-work algorithm extensions: pFedMe [11] client and SAFA/FedSA-style
staleness-discounted aggregation [20][21]."""
import numpy as np
import pytest

from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model


def _payload(v):
    return {"w": np.array([v], dtype=np.float32)}


def test_staleness_discount_weights_fresh_higher():
    """λ<1: a fresh gradient (τ=0) outweighs a stale one (τ=2)."""
    cfg = ServerConfig(n_ues=3, participants_per_round=2, staleness_bound=10,
                       beta=1.0, staleness_discount=0.5)
    srv = SemiSyncServer(_payload(0.0), cfg)
    # advance two rounds via UE0/UE1 so UE2 (never refreshed) has τ=2
    srv.on_arrival(0, _payload(0.0))
    srv.on_arrival(1, _payload(0.0))
    srv.on_arrival(0, _payload(0.0))
    srv.on_arrival(1, _payload(0.0))
    w_before = float(srv.params["w"][0])
    srv.on_arrival(0, _payload(1.0))        # fresh, τ=0, weight 1
    res = srv.on_arrival(2, _payload(1.0))  # stale, τ=2, weight 0.25
    # weighted mean = (1·1 + 0.25·1)/1.25 = 1 → same as unweighted here for
    # identical payloads; use DIFFERENT payloads to discriminate:
    srv2 = SemiSyncServer(_payload(0.0), cfg)
    srv2.on_arrival(0, _payload(0.0))
    srv2.on_arrival(1, _payload(0.0))
    srv2.on_arrival(0, _payload(0.0))
    srv2.on_arrival(1, _payload(0.0))
    base = float(srv2.params["w"][0])
    srv2.on_arrival(0, _payload(4.0))       # fresh says +4
    r2 = srv2.on_arrival(2, _payload(0.0))  # stale says 0
    # weighted mean = (1·4 + 0.25·0)/1.25 = 3.2 → Δw = −β·3.2
    got = float(r2["params"]["w"][0]) - base
    assert abs(got + 3.2) < 1e-5, got

    # λ=1 (paper) gives the plain mean = 2 → Δw = −2
    cfg1 = ServerConfig(n_ues=3, participants_per_round=2, staleness_bound=10,
                        beta=1.0, staleness_discount=1.0)
    srv3 = SemiSyncServer(_payload(0.0), cfg1)
    srv3.on_arrival(0, _payload(0.0))
    srv3.on_arrival(1, _payload(0.0))
    srv3.on_arrival(0, _payload(0.0))
    srv3.on_arrival(1, _payload(0.0))
    base3 = float(srv3.params["w"][0])
    srv3.on_arrival(0, _payload(4.0))
    r3 = srv3.on_arrival(2, _payload(0.0))
    assert abs(float(r3["params"]["w"][0]) - base3 + 2.0) < 1e-5


@pytest.fixture(scope="module")
def fl_setup():
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=8, participants_per_round=3, staleness_bound=3,
                    alpha=0.03, beta=0.07, inner_batch=16, outer_batch=16,
                    hessian_batch=16))
    model = build_model(cfg.model)
    clients = partition_noniid(synthetic_mnist(n=1600, seed=13), 8, n_labels=4,
                               seed=13)
    return cfg, model, clients


def test_pfedme_converges(fl_setup):
    cfg, model, clients = fl_setup
    import dataclasses
    cfg = dataclasses.replace(cfg, fl=dataclasses.replace(
        cfg.fl, beta=0.005, pfedme_lambda=15.0, pfedme_steps=5))
    res = run_simulation(cfg, model, clients, algorithm="pfedme", mode="semi",
                         max_rounds=15, eval_every=15, seed=13)
    assert np.isfinite(res.losses[-1])
    assert res.losses[-1] < res.losses[0]


def test_staleness_discount_in_simulation(fl_setup):
    cfg, model, clients = fl_setup
    import dataclasses
    cfg = dataclasses.replace(cfg, fl=dataclasses.replace(
        cfg.fl, staleness_discount=0.7))
    res = run_simulation(cfg, model, clients, algorithm="perfed", mode="semi",
                         max_rounds=12, eval_every=12, seed=13)
    assert np.isfinite(res.losses[-1])
    assert res.losses[-1] < res.losses[0]
