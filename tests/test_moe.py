"""MoE layer: routing invariants, capacity behaviour, gather-vs-EP parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig
from repro.models import layers as L


def _cfg(e=4, k=2, cap=8.0, shared=0):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=128, dtype="float32",
        moe=MoEConfig(num_experts=e, experts_per_token=k, expert_d_ff=64,
                      capacity_factor=cap, num_shared_experts=shared))


def test_moe_gather_runs_and_is_finite(rng):
    cfg = _cfg()
    p = L.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, 32))
    out, aux = L.moe_apply_gather(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.0


def test_moe_matches_dense_expert_oracle(rng):
    """With capacity high enough to drop nothing, the gather implementation
    must equal the naive 'every expert on every token, masked combine'."""
    cfg = _cfg(e=4, k=2, cap=16.0)
    p = L.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 8, 32))
    out, _ = L.moe_apply_gather(p, x, cfg)

    # oracle
    t = x.reshape(-1, 32)
    logits = t @ p["router"]
    full = jax.nn.softmax(logits, -1)
    probs, idx = jax.lax.top_k(full, 2)
    probs = probs / probs.sum(-1, keepdims=True)
    dense = []
    for e in range(4):
        h = jax.nn.silu(t @ p["moe_gate"][e]) * (t @ p["moe_up"][e])
        dense.append(h @ p["moe_down"][e])
    dense = jnp.stack(dense, 1)                          # [T, E, d]
    want = jnp.zeros_like(t)
    for kk in range(2):
        sel = jnp.take_along_axis(dense, idx[:, kk][:, None, None],
                                  axis=1)[:, 0]
        want = want + probs[:, kk][:, None] * sel
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_low_capacity_drops_tokens(rng):
    cfg_hi = _cfg(cap=16.0)
    cfg_lo = dataclasses.replace(cfg_hi, moe=dataclasses.replace(
        cfg_hi.moe, capacity_factor=0.25))
    p = L.moe_init(rng, cfg_hi)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, 32))
    out_hi, _ = L.moe_apply_gather(p, x, cfg_hi)
    out_lo, _ = L.moe_apply_gather(p, x, cfg_lo)
    # dropped tokens → different (smaller-norm) output
    assert float(jnp.linalg.norm(out_lo)) < float(jnp.linalg.norm(out_hi))


def test_shared_experts_added(rng):
    cfg = _cfg(shared=1)
    p = L.moe_init(rng, cfg)
    assert "shared_gate" in p
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 8, 32))
    out, _ = L.moe_apply_gather(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


def test_aux_loss_balanced_vs_skewed(rng):
    """Load-balance loss must be ≈ coef at uniform routing and higher when
    the router collapses onto one expert."""
    cfg = _cfg(e=4, k=1)
    e = cfg.moe
    t = 512
    # positive features so a one-hot router column dominates every token
    xf = jnp.abs(jax.random.normal(rng, (t, 32))) + 0.1
    p = L.moe_init(rng, cfg)
    # uniform router → aux ≈ coef
    p_uni = dict(p, router=jnp.zeros_like(p["router"]))
    _, _, aux_uni = L._route(p_uni, xf, e)
    # collapsed router → aux ≈ E · coef
    collapsed = jnp.zeros_like(p["router"]).at[:, 0].set(20.0)
    p_col = dict(p, router=collapsed)
    _, _, aux_col = L._route(p_col, xf, e)
    assert float(aux_col) > 2.5 * float(aux_uni)
