"""attn_impl="pallas" end-to-end: the flash kernel inside a real model
forward must match the XLA sdpa path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["yi_6b", "mixtral_8x22b"])
def test_pallas_attention_matches_xla_path(arch, rng):
    base = get_config(arch).reduced()
    # head_dim and seq aligned for the kernel's 128-block default? use small
    # blocks via seq 128 (padding path covers the rest)
    cfg_x = dataclasses.replace(base, attn_impl="xla", dtype="float32")
    cfg_p = dataclasses.replace(base, attn_impl="pallas", dtype="float32")
    model_x = build_model(cfg_x)
    model_p = build_model(cfg_p)
    params = model_x.init(rng)
    toks = jax.random.randint(rng, (2, 96), 0, base.vocab_size)
    lx, _, _ = model_x.forward(params, toks)
    lp, _, _ = model_p.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


def test_pallas_sliding_window_in_model(rng):
    base = get_config("mixtral_8x22b").reduced()     # native SWA config
    assert base.sliding_window > 0
    cfg_x = dataclasses.replace(base, attn_impl="xla", dtype="float32")
    cfg_p = dataclasses.replace(base, attn_impl="pallas", dtype="float32")
    model_x = build_model(cfg_x)
    model_p = build_model(cfg_p)
    params = model_x.init(rng)
    toks = jax.random.randint(rng, (1, 128), 0, base.vocab_size)
    lx, _, _ = model_x.forward(params, toks)
    lp, _, _ = model_p.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)
