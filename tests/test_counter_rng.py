"""Counter-based fading RNG (``WirelessConfig.rng = "counter"``) and the
batch-wise protocol feed.

Three layers:

* distribution — the splitmix64 → inverse-CDF stream must be Rayleigh to
  moment- and KS-level accuracy (it replaces ``Generator.rayleigh`` draws
  in cycle pricing);
* determinism — a UE's j-th coefficient is a pure function of
  (seed, ue, j), independent of how the event loop batches pricing calls;
* trajectories — counter-stream goldens pinned bitwise on host math, the
  legacy stream bitwise UNchanged (the pre-PR golden), and the batch-wise
  feed reproducing the sequential per-arrival feed on static and
  multi-cell hierarchy runs.
"""
import dataclasses

import numpy as np
import pytest

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          WirelessConfig)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.mobility.multicell import MultiCellNetwork
from repro.models import build_model
from repro.wireless.channel import (EdgeNetwork, counter_fading_seed,
                                    counter_rayleigh, validate_rng_mode)

_DATA = synthetic_mnist(n=600, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _cfg(n=8, a=3, s=3, rng="legacy", **fl_kw):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        wireless=WirelessConfig(rng=rng),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8, **fl_kw))


def _clients(n=8, seed=0):
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


# ---------------------------------------------------------------------------
# distribution: the counter stream is Rayleigh
# ---------------------------------------------------------------------------

def _counter_sample(n=200_000, seed=7, scale=40.0):
    base = counter_fading_seed(seed)
    ues = np.arange(n) % 1024
    counters = np.arange(n) // 1024
    return counter_rayleigh(base, ues, counters, scale)


def test_counter_rayleigh_moments():
    scale = 40.0
    h = _counter_sample(scale=scale)
    assert (h > 0).all() and np.isfinite(h).all()
    # Rayleigh(σ): mean σ√(π/2), var (2 − π/2)σ²
    assert abs(h.mean() - scale * np.sqrt(np.pi / 2)) < 0.5
    assert abs(h.var() - (2 - np.pi / 2) * scale ** 2) < 10.0


def test_counter_rayleigh_ks_against_cdf():
    """One-sample Kolmogorov–Smirnov against F(h) = 1 − exp(−h²/2σ²),
    hand-rolled (scipy-free).  n = 2·10⁵ → the 1% critical value of the
    KS statistic is 1.63/√n ≈ 0.00364."""
    scale = 40.0
    h = np.sort(_counter_sample(scale=scale))
    n = len(h)
    cdf = 1.0 - np.exp(-h * h / (2.0 * scale * scale))
    emp_hi = np.arange(1, n + 1) / n
    emp_lo = np.arange(0, n) / n
    ks = max(np.abs(emp_hi - cdf).max(), np.abs(cdf - emp_lo).max())
    assert ks < 1.63 / np.sqrt(n), f"KS statistic {ks:.5f}"


def test_counter_rayleigh_uniform_bits_distinct_per_ue_and_seed():
    c = np.zeros(64, dtype=np.uint64)
    a = counter_rayleigh(counter_fading_seed(0), np.arange(64), c, 40.0)
    b = counter_rayleigh(counter_fading_seed(1), np.arange(64), c, 40.0)
    assert len(np.unique(a)) == 64           # no lane collisions
    assert not np.array_equal(a, b)          # seed separation
    np.testing.assert_array_equal(
        a, counter_rayleigh(counter_fading_seed(0), np.arange(64), c, 40.0))


def test_validate_rng_mode():
    assert validate_rng_mode("legacy") == "legacy"
    assert validate_rng_mode("counter") == "counter"
    with pytest.raises(ValueError, match="unknown fading rng"):
        validate_rng_mode("quantum")
    with pytest.raises(ValueError, match="unknown fading rng"):
        EdgeNetwork.drop(WirelessConfig(rng="quantum"), 4)


# ---------------------------------------------------------------------------
# determinism: value of (seed, ue, j) independent of call batching
# ---------------------------------------------------------------------------

def test_fading_lanes_independent_of_call_batching():
    wl = WirelessConfig(rng="counter")
    net_a = EdgeNetwork.drop(wl, 32, seed=3)
    net_b = EdgeNetwork.drop(wl, 32, seed=3)
    idx = np.array([4, 9, 17, 25, 9, 4, 4])   # repeats advance the counter
    got = np.concatenate([net_a.fading_lanes(idx[:3]),
                          net_a.fading_lanes(idx[3:])])
    want = np.concatenate([net_b.fading_lanes(idx[i:i + 1])
                           for i in range(len(idx))])
    np.testing.assert_array_equal(got, want)


def test_multicell_counter_stream_matches_edge_network():
    """The 1-cell mobile drop and the static drop share (seed, ue, j) —
    the counter stream prices them identically."""
    wl = WirelessConfig(rng="counter")
    e = EdgeNetwork.drop(wl, 16, seed=5)
    m = MultiCellNetwork.drop(wl, 16, n_cells=1, seed=5, speed_mps=0.0)
    idx = np.arange(16)
    np.testing.assert_array_equal(e.fading_lanes(idx), m.fading_lanes(idx))


# ---------------------------------------------------------------------------
# trajectories: counter goldens + legacy parity + feed parity
# ---------------------------------------------------------------------------

def test_counter_static_trajectory_golden():
    """Counter-stream static run, pinned bitwise on host math (the
    counter-mode analogue of the legacy golden in test_driver.py)."""
    res = run_simulation(_cfg(rng="counter"), _MODEL, _clients(),
                         algorithm="perfed", mode="semi", max_rounds=6,
                         eval_every=2, seed=0)
    assert [float(t).hex() for t in res.times] == [
        "0x0.0p+0", "0x1.c54356e93685cp-1",
        "0x1.b627e2dd22877p+0", "0x1.44e6583053d06p+1"]
    assert float(res.total_time).hex() == "0x1.44e6583053d06p+1"
    assert res.rounds.tolist() == [0, 2, 4, 6]
    assert res.payloads_computed == 18


def test_legacy_trajectory_unchanged_by_counter_machinery():
    """``rng="legacy"`` must reproduce the pre-PR golden bitwise: the
    counter state initialised at drop touches neither the main numpy
    stream nor the pricing path."""
    res = run_simulation(_cfg(), _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0)
    assert float(res.total_time).hex() == "0x1.4066315c4298cp+1"


def test_counter_degenerate_mobile_matches_static_bitwise():
    """Counter pricing is a pure function of (seed, ue, draw index), so
    the degenerate mobile run hits the static counter golden exactly."""
    degen = dataclasses.replace(_cfg(rng="counter"),
                                mobility=MobilityConfig(
        enabled=True, speed_mps=0.0, n_cells=1, hierarchy=False))
    res = run_simulation(degen, _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0)
    assert float(res.total_time).hex() == "0x1.44e6583053d06p+1"


def _feed_parity(cfg, make_clients=_clients, *, rounds=6, **kw):
    """Batch-wise feed vs per-arrival sequential feed: identical host
    trajectory (times are pure host math), identical protocol decisions
    (Π), device math equal to float32 tolerance.  ``make_clients`` is a
    factory — client objects carry private RNG state, so each run needs
    a fresh set."""
    seq = run_simulation(cfg, _MODEL, make_clients(), payload_mode="sequential",
                         algorithm="perfed", mode="semi", max_rounds=rounds,
                         eval_every=2, seed=0, **kw)
    bat = run_simulation(cfg, _MODEL, make_clients(), payload_mode="batched",
                         algorithm="perfed", mode="semi", max_rounds=rounds,
                         eval_every=2, seed=0, **kw)
    np.testing.assert_array_equal(seq.times, bat.times)
    np.testing.assert_array_equal(seq.pi, bat.pi)
    assert seq.total_time == bat.total_time
    np.testing.assert_allclose(seq.losses, bat.losses, rtol=2e-5, atol=1e-6)
    return seq, bat


def test_batch_feed_matches_sequential_static_mixed_signatures():
    """Tiny shards force mixed batch-shape signatures (triplet sizes
    truncate to the shard), so the batched run exercises the multi-group
    stacked feed (gather + inverse permute), segment-pending bookkeeping,
    and the singleton ``_single`` ride."""
    cfg = _cfg(n=6, a=2, s=2)

    def tiny():
        return partition_noniid(synthetic_mnist(n=60, seed=3), 6, n_labels=3, seed=1)

    sigs = {c.triplet_sizes(8, 8, 8) for c in tiny()}
    assert len(sigs) > 1, f"expected mixed signatures, got {sigs}"
    _feed_parity(cfg, tiny, rounds=5)


def test_batch_feed_matches_sequential_hierarchy():
    """Multi-cell hierarchy: drains interleave cells, so the batched run
    exercises the per-cell segment split with the closing cell fed last
    (visiting-staleness reads precede the round advance)."""
    cfg = dataclasses.replace(
        _cfg(n=8, a=4, s=6, first_order=True, eta_mode="distance"),
        mobility=MobilityConfig(enabled=True, model="static", speed_mps=0.0,
                                n_cells=2, hierarchy=True,
                                cell_participants=2, cloud_sync_every=3))
    seq, bat = _feed_parity(cfg, bandwidth_policy="equal")
    assert seq.cloud_rounds == bat.cloud_rounds


def test_batch_feed_matches_sequential_moving_hierarchy():
    """Moving UEs: handovers + departed arrivals go through the batch
    feed's transient visiting-version stamping."""
    cfg = dataclasses.replace(
        _cfg(n=8, a=4, s=4, first_order=True, eta_mode="distance",
             rng="counter"),
        mobility=MobilityConfig(enabled=True, model="random_waypoint",
                                speed_mps=30.0, n_cells=2, hierarchy=True,
                                cell_participants=2, cloud_sync_every=0,
                                step_s=0.05))
    seq, bat = _feed_parity(cfg, bandwidth_policy="equal")
    assert seq.handovers == bat.handovers
    assert seq.departed_arrivals == bat.departed_arrivals
