"""Non-iid partitioner + synthetic dataset properties (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.data import (partition_noniid, synthetic_mnist,
                        synthetic_shakespeare)
from repro.data.partition import sample_triplet_many, sequence_clients


@given(st.integers(2, 20), st.integers(1, 10), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_clients_hold_at_most_l_labels(n_clients, n_labels, seed):
    data = synthetic_mnist(n=800, seed=seed)
    clients = partition_noniid(data, n_clients, n_labels, seed=seed)
    assert len(clients) == n_clients
    for c in clients:
        # ≤ l classes (a tiny shard may be padded with random extras)
        assert len(c.labels_held) <= max(n_labels, 1) + 2
        assert len(c) >= 1


def test_lower_l_is_more_heterogeneous():
    data = synthetic_mnist(n=2000, seed=0)
    c2 = partition_noniid(data, 10, 2, seed=0)
    c8 = partition_noniid(data, 10, 8, seed=0)
    mean_labels_2 = np.mean([len(c.labels_held) for c in c2])
    mean_labels_8 = np.mean([len(c.labels_held) for c in c8])
    assert mean_labels_2 < mean_labels_8


def test_sizes_unbalanced():
    data = synthetic_mnist(n=4000, seed=1)
    clients = partition_noniid(data, 10, 4, seed=1)
    sizes = np.array([len(c) for c in clients])
    assert sizes.max() > 1.3 * sizes.min()      # "different local data size"


def test_triplet_batches_independent():
    data = synthetic_mnist(n=500, seed=2)
    c = partition_noniid(data, 4, 4, seed=2)[0]
    t = c.sample_triplet(8, 8, 8)
    assert set(t) == {"inner", "outer", "hessian"}
    assert not np.array_equal(t["inner"]["x"], t["outer"]["x"])


def test_sample_triplet_many_bitwise_matches_loop():
    """The stacked sampler must consume each client's private generator
    exactly as the per-UE ``sample_triplet`` loop does — the batch-wise
    driver feed relies on this to keep legacy trajectories bitwise."""
    data = synthetic_mnist(n=60, seed=3)
    a = partition_noniid(data, 6, 3, seed=1)
    b = partition_noniid(data, 6, 3, seed=1)
    groups = {}
    for i, c in enumerate(a):
        groups.setdefault(c.triplet_sizes(8, 8, 8), []).append(i)
    assert len(groups) > 1                      # mixed shard sizes
    for idx in groups.values():
        stacked = sample_triplet_many([a[i] for i in idx], 8, 8, 8)
        loop = [b[i].sample_triplet(8, 8, 8) for i in idx]
        for part in ("inner", "outer", "hessian"):
            for k in stacked[part]:
                np.testing.assert_array_equal(
                    stacked[part][k],
                    np.stack([t[part][k] for t in loop]))


def test_sample_triplet_many_rejects_mixed_sizes_and_empty():
    data = synthetic_mnist(n=60, seed=3)
    clients = partition_noniid(data, 6, 3, seed=1)
    assert len({c.triplet_sizes(8, 8, 8) for c in clients}) > 1
    with pytest.raises(ValueError, match="mixed triplet sizes"):
        sample_triplet_many(clients, 8, 8, 8)
    with pytest.raises(ValueError, match="at least one client"):
        sample_triplet_many([], 8, 8, 8)


def test_mnist_learnable_structure():
    d = synthetic_mnist(n=1000, seed=0)
    # same-class images correlate more than cross-class
    x, y = d["x"].reshape(1000, -1), d["y"]
    idx0 = np.where(y == 0)[0][:20]
    idx1 = np.where(y == 1)[0][:20]
    same = np.corrcoef(x[idx0[0]], x[idx0[1]])[0, 1]
    cross = np.corrcoef(x[idx0[0]], x[idx1[0]])[0, 1]
    assert same > cross


def test_shakespeare_roles_differ():
    roles = synthetic_shakespeare(n_roles=3, chars_per_role=500, seq_len=16)
    clients = sequence_clients(roles, 3)
    assert len(clients) == 3
    t0 = clients[0].data["tokens"]
    assert t0.shape[1] == 16
    # targets are tokens shifted by one
    tok, targ = clients[0].data["tokens"], clients[0].data["targets"]
    assert np.array_equal(tok[0, 1:], targ[0, :-1])
