"""SPMD semi-synchronous step (core/semi_sync.py) semantics on one device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ExperimentConfig, FLConfig, ModelConfig, TrainConfig
from repro.core import semi_sync
from repro.models import build_model
from repro.optim import make_optimizer
from repro.utils import tree_norm, tree_sub


@pytest.fixture(scope="module")
def setup():
    cfg = ExperimentConfig(
        model=ModelConfig(name="mnist_dnn", family="small", d_model=16,
                          vocab_size=10, dtype="float32"),
        fl=FLConfig(alpha=0.02, beta=0.1, staleness_bound=2),
        train=TrainConfig(grad_clip=0.0))
    model = build_model(cfg.model)
    opt = make_optimizer("sgd")
    return cfg, model, opt


def _cohort_batches(rng, n_cohorts, b=8):
    def one(r):
        rx, ry = jax.random.split(r)
        return {"x": jax.random.normal(rx, (n_cohorts, b, 28, 28)),
                "y": jax.random.randint(ry, (n_cohorts, b), 0, 10)}
    r1, r2, r3 = jax.random.split(rng, 3)
    return {"inner": one(r1), "outer": one(r2), "hessian": one(r3)}


def test_masked_aggregation_matches_manual(setup, rng):
    cfg, model, opt = setup
    n = 3
    step = semi_sync.make_semi_sync_step(model, cfg, opt, n)
    state = semi_sync.init_state(model, rng, opt, n)
    # hand-fill buffers with known values
    bufs = jax.tree.map(
        lambda b: jnp.stack([jnp.full(b.shape[1:], float(i + 1), b.dtype)
                             for i in range(n)]), state.buffers)
    state = state._replace(buffers=bufs)
    mask = jnp.array([1.0, 0.0, 1.0])
    batches = _cohort_batches(rng, n)
    new_state, metrics = jax.jit(step)(state, batches, mask, rng)
    # Eq. (8): w ← w − β/2 · (buf_0 + buf_2) = w − 0.1/2·(1+3)
    delta = jax.tree.map(lambda new, old: new - old, new_state.params,
                         state.params)
    for leaf in jax.tree.leaves(delta):
        np.testing.assert_allclose(np.asarray(leaf), -0.1 / 2 * 4.0, atol=1e-5)


def test_refresh_only_scheduled_cohorts(setup, rng):
    cfg, model, opt = setup
    n = 3
    step = semi_sync.make_semi_sync_step(model, cfg, opt, n)
    state = semi_sync.init_state(model, rng, opt, n)
    mask = jnp.array([1.0, 0.0, 1.0])
    batches = _cohort_batches(rng, n)
    new_state, _ = jax.jit(step)(state, batches, mask, rng)
    # cohort 1 keeps zeros; 0 and 2 refreshed to non-zero fresh grads
    b0 = jax.tree.leaves(new_state.buffers)[0]
    assert float(jnp.abs(b0[1]).max()) == 0.0
    assert float(jnp.abs(b0[0]).max()) > 0.0
    assert float(jnp.abs(b0[2]).max()) > 0.0
    np.testing.assert_array_equal(np.asarray(new_state.staleness), [0, 1, 0])


def test_stale_cohort_forced_refresh(setup, rng):
    cfg, model, opt = setup
    n = 2
    step = jax.jit(semi_sync.make_semi_sync_step(model, cfg, opt, n))
    state = semi_sync.init_state(model, rng, opt, n)
    batches = _cohort_batches(rng, n)
    mask = jnp.array([1.0, 0.0])
    # S = 2: after 3 rounds of never being scheduled, cohort 1 must refresh
    for _ in range(3):
        state, _ = step(state, batches, mask, rng)
    assert int(state.staleness[1]) == 3
    state, _ = step(state, batches, mask, rng)
    assert int(state.staleness[1]) == 0       # τ > S triggered the refresh


def test_single_cohort_is_synchronous_perfedavg(setup, rng):
    """n_cohorts=1, mask=[1] ≡ make_train_step(perfed) after one warm-up
    round (the first semi-sync round applies the zero-initialised buffer)."""
    cfg, model, opt = setup
    semi = jax.jit(semi_sync.make_semi_sync_step(model, cfg, opt, 1))
    plain = jax.jit(semi_sync.make_train_step(model, cfg, opt,
                                              perfed_step=True))
    s_state = semi_sync.init_state(model, rng, opt, 1)
    p_state = semi_sync.init_train_state(model, rng, opt)
    batches = _cohort_batches(rng, 1)
    flat_batches = jax.tree.map(lambda x: x[0], batches)
    mask = jnp.ones((1,))
    # round 1: buffer zero → params unchanged, buffer filled
    s_state, _ = semi(s_state, batches, mask, rng)
    assert float(tree_norm(tree_sub(s_state.params, p_state.params))) < 1e-7
    # round 2 applies exactly the gradient plain computes
    s_state, _ = semi(s_state, batches, mask, rng)
    p_state, _ = plain(p_state, flat_batches, rng)
    err = float(tree_norm(tree_sub(s_state.params, p_state.params)))
    assert err < 1e-5, err
