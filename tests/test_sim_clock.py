"""The vectorized simulation clock (PR 5).

* Grid-aligned mobility: integration ticks live on the global ``step_s``
  grid and a T-tick advance makes one batched ``[T, n, D]`` RNG draw, so
  the draw schedule — and hence the trajectory — is a pure function of
  *which ticks elapsed*, never of the ``advance_to`` call pattern (the
  partial-tick schedule bug this PR fixes).
* Safe-radius incremental re-association is bitwise identical to the full
  ``[n, k]`` recompute across randomized trajectories, speeds, and both
  association policies (hypothesis property).
* Batched eval (``engine.eval_many``) matches the sequential per-client
  ``eval_one`` numerically and costs one dispatch per shape-uniform eval
  point; shape-heterogeneous cohorts fall back to the eval_one jit bitwise.
* Departed-UE restarts are priced as one batch per drain.
* Block-chunked fading draws are bitwise the single big ``[k, n]`` call.
"""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import (HealthCheck, given, settings,
                                          strategies as st)

import jax

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          WirelessConfig)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.data.partition import ClientDataset
from repro.fl.engine import SimulationEngine
from repro.fl.simulation import run_simulation
from repro.mobility.models import Area, GaussMarkov, RandomWaypoint
from repro.mobility.multicell import MultiCellNetwork
from repro.models import build_model

AREA = Area(0.0, 0.0, 400.0, 400.0)

_DATA = synthetic_mnist(n=900, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _fl_cfg(n=8, **kw):
    return FLConfig(n_ues=n, participants_per_round=4, staleness_bound=6,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8, first_order=True, **kw)


# ---------------------------------------------------------------------------
# batched stepping ≡ sequential stepping, and call-pattern independence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", [RandomWaypoint(speed_mps=12.0, pause_s=2.0),
                                   GaussMarkov(speed_mps=12.0)])
def test_step_many_bitwise_equals_sequential_steps(model):
    rng_a = np.random.default_rng(5)
    rng_b = np.random.default_rng(5)
    pos = AREA.uniform(rng_a, 32)
    AREA.uniform(rng_b, 32)               # keep the streams aligned
    st_a = model.init_state(32, AREA, rng_a)
    st_b = model.init_state(32, AREA, rng_b)
    pos_a, pos_b = pos.copy(), pos.copy()
    pos_a, st_a = model.step_many(pos_a, st_a, 7, 1.0, AREA, rng_a)
    for _ in range(7):
        pos_b, st_b = model.step(pos_b, st_b, 1.0, AREA, rng_b)
    np.testing.assert_array_equal(pos_a, pos_b)
    for k in st_a:
        np.testing.assert_array_equal(st_a[k], st_b[k])


@pytest.mark.parametrize("model", [RandomWaypoint(speed_mps=9.0),
                                   GaussMarkov(speed_mps=9.0)])
def test_step_many_block_chunked_draws_bitwise_stable(model, monkeypatch):
    """Tick blocks bounded by MAX_DRAW_DOUBLES consume the bitstream
    exactly like one unbounded [ticks, n, D] draw."""
    from repro.mobility import models as mm

    def roll(ticks):
        rng = np.random.default_rng(3)
        pos = AREA.uniform(rng, 16)
        st_m = model.init_state(16, AREA, rng)
        return model.step_many(pos, st_m, ticks, 1.0, AREA, rng)[0]

    want = roll(11)
    monkeypatch.setattr(mm, "MAX_DRAW_DOUBLES", 16 * 3)   # 1 tick per block
    np.testing.assert_array_equal(roll(11), want)


@pytest.mark.parametrize("mobility", ["random_waypoint", "gauss_markov"])
def test_advance_schedule_independent_of_call_pattern(mobility):
    """Regression for the partial-tick draw-schedule bug:
    ``advance_to(t1); advance_to(t2)`` must consume exactly the same
    mobility RNG schedule — and land on the same positions — as a single
    ``advance_to(t2)``."""
    kw = dict(n_cells=4, seed=9, mobility=mobility, speed_mps=25.0)
    net_a = MultiCellNetwork.drop(WirelessConfig(), 64, **kw)
    net_b = MultiCellNetwork.drop(WirelessConfig(), 64, **kw)
    for t in (1.3, 2.7, 4.0, 9.9):        # partial and exact tick boundaries
        net_a.advance_to(t)
    net_b.advance_to(9.9)
    np.testing.assert_array_equal(net_a.positions, net_b.positions)
    np.testing.assert_array_equal(net_a.assoc, net_b.assoc)
    np.testing.assert_array_equal(net_a.distances, net_b.distances)
    assert net_a._ticks == net_b._ticks == 9
    assert net_a.time == net_b.time == 9.9
    # the auxiliary streams are in the same state afterwards
    assert net_a.mob_rng.random() == net_b.mob_rng.random()


def test_sub_tick_advance_is_pure_clock_update():
    net = MultiCellNetwork.drop(WirelessConfig(), 16, n_cells=2, seed=0,
                                mobility="random_waypoint", speed_mps=30.0)
    p0, d0 = net.positions.copy(), net.distances.copy()
    assert net.advance_to(0.9) == []
    np.testing.assert_array_equal(net.positions, p0)
    np.testing.assert_array_equal(net.distances, d0)
    assert net.time == 0.9 and net._ticks == 0
    assert net.advance_to(1.0) != [] or net._ticks == 1   # tick completes


def test_unknown_reassoc_mode_rejected():
    with pytest.raises(ValueError, match="reassoc"):
        MultiCellNetwork.drop(WirelessConfig(), 8, n_cells=2,
                              reassoc="psychic")


# ---------------------------------------------------------------------------
# safe-radius incremental ≡ full [n, k] recompute (bitwise)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10), st.sampled_from([5.0, 30.0, 90.0]),
       st.integers(2, 5),
       st.sampled_from(["nearest", "load_aware"]),
       st.sampled_from(["random_waypoint", "gauss_markov"]))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_safe_radius_bitwise_equals_full_recompute(seed, speed, n_cells,
                                                   association, mobility):
    kw = dict(n_cells=n_cells, seed=seed, mobility=mobility,
              speed_mps=speed, association=association,
              cell_bandwidth_hz=(2e6,) + (5e5,) * (n_cells - 1))
    inc = MultiCellNetwork.drop(WirelessConfig(), 48, reassoc="safe_radius",
                                **kw)
    ref = MultiCellNetwork.drop(WirelessConfig(), 48, reassoc="full", **kw)
    times = np.cumsum(np.random.default_rng(seed).uniform(0.3, 4.0, size=12))
    for t in times:
        ev_inc = inc.advance_to(float(t))
        ev_ref = ref.advance_to(float(t))
        assert ev_inc == ev_ref
        np.testing.assert_array_equal(inc.positions, ref.positions)
        np.testing.assert_array_equal(inc.assoc, ref.assoc)
        np.testing.assert_array_equal(inc.distances, ref.distances)
    assert inc.handovers == ref.handovers


def test_safe_radius_skips_rescoring_settled_ues():
    """The point of the margins: once established, slow UEs far from any
    cell boundary are not re-scored (their anchors stay put)."""
    net = MultiCellNetwork.drop(WirelessConfig(), 256, n_cells=4, seed=3,
                                mobility="random_waypoint", speed_mps=1.0)
    net.advance_to(1.0)                   # establishes margins/anchors
    anchors = net._anchor.copy()
    net.advance_to(2.0)                   # 1 m of movement ≪ most margins
    assert (net._margin > 0).any()
    settled = np.isclose(net._anchor, anchors).all(axis=1)
    assert settled.sum() > 128            # most UEs untouched


# ---------------------------------------------------------------------------
# batched eval
# ---------------------------------------------------------------------------

def _uniform_clients(n, test_size=16, seed=0):
    """Clients whose train/test shapes all match (one vmap group)."""
    out = []
    for ci, c in enumerate(partition_noniid(_DATA, n, n_labels=4, seed=seed)):
        test = {k: v[:test_size] for k, v in _DATA.items()}
        out.append(ClientDataset(data=c.data, test=test,
                                 labels_held=c.labels_held,
                                 rng=np.random.default_rng(100 + ci)))
    return out


def test_eval_many_matches_sequential_and_is_one_dispatch():
    fl = _fl_cfg()
    engine = SimulationEngine(_MODEL, fl, "perfed")
    params = _MODEL.init(jax.random.PRNGKey(0))
    clients = _uniform_clients(6)
    batches = [{"inner": c.sample(fl.inner_batch), "outer": dict(c.test)}
               for c in clients]
    rngs = list(jax.random.split(jax.random.PRNGKey(7), len(clients)))

    want = [engine.eval_one(params, b, r) for b, r in zip(batches, rngs)]
    d0 = engine.eval_dispatches
    pl, gl, ac = engine.eval_many(params, batches, rngs)
    assert engine.eval_dispatches - d0 == 1      # whole cohort, one dispatch
    np.testing.assert_allclose(pl, [float(p) for p, _, _ in want], rtol=1e-6)
    np.testing.assert_allclose(gl, [float(g) for _, g, _ in want], rtol=1e-6)


def test_eval_many_heterogeneous_shapes_fall_back_bitwise():
    """Singleton shape groups ride the same jitted scalar eval as the
    sequential path — distinct-shape cohorts reproduce it bit for bit."""
    fl = _fl_cfg()
    engine = SimulationEngine(_MODEL, fl, "perfed")
    params = _MODEL.init(jax.random.PRNGKey(1))
    clients = partition_noniid(_DATA, 4, n_labels=4, seed=2)
    batches = [{"inner": c.sample(fl.inner_batch), "outer": dict(c.test)}
               for c in clients]
    sizes = {len(next(iter(b["outer"].values()))) for b in batches}
    assert len(sizes) > 1                 # actually heterogeneous
    rngs = list(jax.random.split(jax.random.PRNGKey(8), len(clients)))
    want = [engine.eval_one(params, b, r) for b, r in zip(batches, rngs)]
    pl, gl, ac = engine.eval_many(params, batches, rngs)
    np.testing.assert_array_equal(pl, [float(p) for p, _, _ in want])
    np.testing.assert_array_equal(gl, [float(g) for _, g, _ in want])


def test_driver_eval_point_costs_one_dispatch():
    cfg = ExperimentConfig(model=get_config("mnist_dnn"), fl=_fl_cfg())
    engine = SimulationEngine(_MODEL, cfg.fl, "perfed")
    clients = _uniform_clients(8)
    res = run_simulation(cfg, _MODEL, clients, algorithm="perfed",
                         mode="semi", max_rounds=4, eval_every=2, seed=0,
                         engine=engine)
    n_eval_points = len(res.times)
    assert n_eval_points >= 2
    assert engine.eval_dispatches == n_eval_points
    assert np.isfinite(res.losses).all()


# ---------------------------------------------------------------------------
# batched departed-UE restarts
# ---------------------------------------------------------------------------

def test_departed_restarts_priced_as_one_batch(monkeypatch):
    """Force TWO mid-flight handovers out of cell 0; their uploads close
    cell 0's round in one drain, so the driver must price both restart
    cycles with a single ``cycle_durations`` call."""
    from repro.fl.mobile import MobileAdapter

    n = 12                                # seed-0 drop: 6 UEs per cell
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=_fl_cfg(n=n, eta_mode="distance"),
        mobility=MobilityConfig(enabled=True, model="static", speed_mps=0.0,
                                n_cells=2, hierarchy=True,
                                cell_participants=2, cloud_sync_every=0))
    state = {"calls": 0, "moved": []}
    orig = MultiCellNetwork.advance_to

    def patched(self, t):
        events = orig(self, t)
        state["calls"] += 1
        if not state["moved"] and state["calls"] >= 1:
            members = np.nonzero(self.assoc == 0)[0]
            if len(members) > 3:          # keep cell 0 able to close rounds
                for u in members[:2]:
                    self.assoc[int(u)] = 1
                    self.handovers += 1
                    state["moved"].append(int(u))
                    events = events + [(int(u), 0, 1)]
        return events

    monkeypatch.setattr(MultiCellNetwork, "advance_to", patched)
    priced = []
    orig_pre = MobileAdapter.pre_requeue
    monkeypatch.setattr(
        MobileAdapter, "pre_requeue",
        lambda self, ues: (priced.append([int(u) for u in ues]),
                           orig_pre(self, ues))[1])
    clients = partition_noniid(_DATA, n, n_labels=4, seed=0)
    res = run_simulation(cfg, _MODEL, clients, algorithm="perfed",
                         mode="semi", bandwidth_policy="equal", max_rounds=8,
                         eval_every=0, seed=0, payload_mode="sequential")
    assert len(state["moved"]) == 2 and res.departed_arrivals >= 2
    # both departed UEs restarted TOGETHER: one pricing call covers the set
    assert any(sorted(call) == sorted(state["moved"]) for call in priced)
    # liveness: neither departed UE vanished from the schedule
    for u in state["moved"]:
        assert res.pi[:, u].sum() >= 1


# ---------------------------------------------------------------------------
# block-chunked fading draws
# ---------------------------------------------------------------------------

def test_chunked_fading_bitwise_equals_single_draw(monkeypatch):
    from benchmarks.requeue import PricingShim, legacy_durations
    from repro.fl import driver as drv
    from repro.wireless.channel import EdgeNetwork

    wl = WirelessConfig()
    n = 64
    net_a = EdgeNetwork.drop(wl, n, seed=11)
    net_b = EdgeNetwork.drop(wl, n, seed=11)
    bw = np.full(n, wl.total_bandwidth_hz / n)
    d_i = np.full(n, 24)
    monkeypatch.setattr(drv, "FADING_BLOCK", 5 * n)   # 5-row blocks
    fn = drv.make_cycle_duration_fn(PricingShim(net_a, bw), wl, 1e6, d_i)
    for k in (n, 17, 3):                  # spans multiple blocks, then not
        ues = np.arange(n)[:k]
        got = fn(ues)
        want = legacy_durations(net_b, wl, bw, d_i, 1e6, ues)
        np.testing.assert_array_equal(got, want)
