"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhld
from repro.kernels.fused_adam import fused_adam_flat
from repro.kernels.ssd_scan import ssd_chunk_pallas
from repro.kernels.stale_aggregate import stale_aggregate_flat


# ---------------------------------------------------------------- flash ----

FLASH_SHAPES = [
    # (B, Hq, Hkv, L, D, block)
    (1, 2, 2, 64, 32, 32),      # MHA
    (2, 4, 2, 96, 32, 32),      # GQA 2:1, ragged L vs block
    (1, 8, 1, 128, 64, 64),     # MQA
    (1, 2, 2, 50, 16, 32),      # L not divisible by block (padding path)
]


@pytest.mark.parametrize("b,hq,hkv,sl,d,blk", FLASH_SHAPES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 24])
def test_flash_attention_matches_ref(b, hq, hkv, sl, d, blk, causal,
                                     window,
                                     rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, hq, sl, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, sl, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, sl, d), jnp.float32)
    got = flash_attention_bhld(q, k, v, causal=causal, window=window,
                               block_q=blk, block_k=blk)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(dtype)
    got = flash_attention_bhld(q, k, v, causal=True, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_flash_model_layout_wrapper(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    got = ops.flash_attention(q, k, v, causal=True)
    want = jnp.moveaxis(ref.attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------------ ssd ----

SSD_SHAPES = [
    (1, 2, 32, 2, 8, 16),
    (2, 3, 64, 4, 16, 8),
    (1, 1, 16, 1, 4, 4),
]


@pytest.mark.parametrize("b,nc,q,h,p,n", SSD_SHAPES)
def test_ssd_chunk_kernel_matches_naive_recurrence(b, nc, q, h, p, n, rng):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, nc, q, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, nc, q, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, nc, q, n), jnp.float32)
    y, st, dec, _ = ssd_chunk_pallas(x, dt, a, bm, cm)
    for ci in range(nc):
        yr, sr, dr = ref.ssd_chunk_ref(x[:, ci], dt[:, ci], a, bm[:, ci],
                                       cm[:, ci])
        np.testing.assert_allclose(np.asarray(y[:, ci]), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st[:, ci]), np.asarray(sr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(dec[:, ci]), np.asarray(dr),
                                   rtol=1e-5, atol=1e-6)


def test_ssd_ops_matches_model_implementation(rng):
    """ops.ssd_chunked (Pallas) ≡ models.ssm.ssd_chunked (pure jnp)."""
    from repro.models.ssm import ssd_chunked as ssd_jnp
    bs, sl, h, p, n = 2, 128, 3, 8, 16
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (bs, sl, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, sl, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (bs, sl, n))
    cm = jax.random.normal(ks[4], (bs, sl, n))
    y1, s1 = ssd_jnp(x, dt, a, bm, cm, 32)
    y2, s2 = ops.ssd_chunked(x, dt, a, bm, cm, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


# ----------------------------------------------------------------- adam ----

@pytest.mark.parametrize("n", [100, 4096, 5000])
@pytest.mark.parametrize("t", [1, 10])
def test_fused_adam_matches_ref(n, t, rng):
    ks = jax.random.split(rng, 4)
    p = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,)) * 0.1
    v = jnp.abs(jax.random.normal(ks[2], (n,))) * 0.01
    g = jax.random.normal(ks[3], (n,))
    np_, nm, nv = fused_adam_flat(p, m, v, g, lr=3e-3, t=t)
    rp, rm, rv = ref.adam_ref(p, m, v, g, lr=3e-3, b1=0.9, b2=0.95,
                              eps=1e-8, t=t)
    np.testing.assert_allclose(np.asarray(np_), np.asarray(rp), atol=1e-6)
    np.testing.assert_allclose(np.asarray(nm), np.asarray(rm), atol=1e-6)
    np.testing.assert_allclose(np.asarray(nv), np.asarray(rv), atol=1e-6)


def test_fused_adam_bf16_params(rng):
    kp, kg = jax.random.split(rng)
    p = jax.random.normal(kp, (512,)).astype(jnp.bfloat16)
    m = jnp.zeros(512)
    v = jnp.zeros(512)
    g = jax.random.normal(kg, (512,))
    np_, _, _ = fused_adam_flat(p, m, v, g, lr=1e-2, t=1)
    assert np_.dtype == jnp.bfloat16


def test_fused_adam_tree_matches_optimizer(rng):
    """kernel pytree wrapper ≡ repro.optim.adam on a small param tree."""
    from repro.optim import adam
    ka, kc = jax.random.split(rng)
    params = {"a": jax.random.normal(ka, (64, 8)),
              "b": {"c": jax.random.normal(kc, (100,))}}
    grads = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, params)
    opt = adam(b1=0.9, b2=0.95, eps=1e-8)
    st = opt.init(params)
    want, _ = opt.update(grads, st, params, 1e-2)
    got, _, _ = ops.fused_adam_tree(params, st["m"], st["v"], grads,
                                    lr=1e-2, t=1)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-6), got, want)


# ------------------------------------------------------- stale aggregate ---

@pytest.mark.parametrize("c,n", [(2, 100), (4, 4096), (3, 9000)])
def test_stale_aggregate_matches_ref(c, n, rng):
    ks = jax.random.split(rng, 3)
    p = jax.random.normal(ks[0], (n,))
    buf = jax.random.normal(ks[1], (c, n))
    mask = (jax.random.uniform(ks[2], (c,)) > 0.4).astype(jnp.float32)
    got = stale_aggregate_flat(p, buf, mask, beta=0.07)
    want = ref.stale_aggregate_ref(p, buf, mask, beta=0.07)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_stale_aggregate_semi_sync_equivalence(rng):
    """Kernel ≡ the semi_sync masked-psum aggregation (β-SGD, no clip)."""
    c, n = 3, 257
    buf = jax.random.normal(rng, (c, n))
    p = jax.random.normal(jax.random.fold_in(rng, 1), (n,))
    mask = jnp.array([1.0, 0.0, 1.0])
    beta = 0.07
    got = stale_aggregate_flat(p, buf, mask, beta=beta)
    agg = jnp.einsum("cn,c->n", buf, mask) / mask.sum()
    want = p - beta * agg
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
