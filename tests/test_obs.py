"""Telemetry layer: no-op fast path, span accounting, read-only tracing
(golden trajectories bitwise unchanged with tracing ON), JSONL schema
round-trip + invariants, trace_report rendering, and reporter levels.
"""
import dataclasses
import io
import time

import numpy as np
import pytest

from repro.config import ExperimentConfig, FLConfig, MobilityConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model
from repro.obs import (NOOP, NoopTracer, Reporter, Tracer, current, use,
                       validate_rows)
from repro.obs import trace as obs_trace
from repro.obs.recorder import (REQUIRED_KEYS, SCHEMA, split_rows,
                                staleness_histogram)
from repro.utils.metrics import read_metrics

_DATA = synthetic_mnist(n=600, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))


def _cfg(n=8, a=3, s=3, **fl_kw):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=s,
                    alpha=0.03, beta=0.07, inner_batch=8, outer_batch=8,
                    hessian_batch=8, **fl_kw))


def _clients(n=8, seed=0):
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


def _mobile_cfg(n=24, **mob_kw):
    kw = dict(enabled=True, model="random_waypoint", speed_mps=30.0,
              n_cells=3, hierarchy=True, cloud_sync_every=4, step_s=0.2)
    kw.update(mob_kw)
    return dataclasses.replace(
        _cfg(n=n, a=max(1, n // 8), s=4, first_order=True),
        mobility=MobilityConfig(**kw))


# ---------------------------------------------------------------------------
# disabled fast path
# ---------------------------------------------------------------------------

def test_noop_is_the_default_current_tracer():
    assert current() is NOOP
    assert obs_trace.CURRENT is NOOP
    assert NOOP.enabled is False and NOOP.device_timing is False


def test_noop_span_is_one_shared_object():
    a = NOOP.span("x")
    b = NOOP.span("y")
    assert a is b                      # no allocation per call site
    with a:
        pass
    assert NOOP.add("c") is None
    assert NOOP.device_call("d", lambda v: v + 1, 41) == 42
    snap = NOOP.snapshot()
    assert snap == {"phase_s": {}, "counts": {}, "device_s": 0.0,
                    "device_phase_s": {}}


def test_use_installs_and_restores_current():
    tr = Tracer()
    with use(tr) as installed:
        assert installed is tr and current() is tr
        with use(None):                # nested None → NOOP
            assert current() is NOOP
        assert current() is tr
    assert current() is NOOP


def test_noop_call_site_cost_is_sub_microsecond():
    # the hot-loop contract: a disabled call site is one attribute fetch
    # plus an empty method call — budget is generous (5 µs/op) so shared
    # CI boxes can't flake, while a regression to real timing syscalls
    # per call (≈ the no-op cost ×50) still fails loudly
    n = 200_000
    tr = obs_trace.CURRENT
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("x"):
            pass
    dt = time.perf_counter() - t0
    assert dt / n < 5e-6, f"no-op span costs {dt/n*1e9:.0f}ns"


# ---------------------------------------------------------------------------
# live tracer accounting
# ---------------------------------------------------------------------------

def test_span_exclusive_time_nesting():
    tr = Tracer()
    with tr.span("outer"):
        time.sleep(0.02)
        with tr.span("inner"):
            time.sleep(0.03)
    assert tr.phase_s["inner"] >= 0.025
    # outer's exclusive time excludes inner's
    assert tr.phase_s["outer"] < 0.03
    assert tr.phase_s["outer"] >= 0.01


def test_counters_accumulate():
    tr = Tracer()
    tr.add("a")
    tr.add("a", 4)
    tr.add("b", 2)
    assert tr.counts == {"a": 5, "b": 2}


def test_device_call_attribution_and_reentrancy():
    tr = Tracer(device=True)

    def inner():
        return tr.device_call("inner", lambda: np.float64(1.0))

    out = tr.device_call("outer", inner)
    assert float(out) == 1.0
    # only the outermost frame accumulated
    assert "outer" in tr.device_phase_s
    assert "inner" not in tr.device_phase_s
    # spans opened inside a device frame are no-ops (no double-booking)
    def spanning():
        with tr.span("nested_host"):
            return 7
    assert tr.device_call("outer", spanning) == 7
    assert "nested_host" not in tr.phase_s


def test_device_timing_off_never_blocks_or_books():
    tr = Tracer(device=False)
    assert tr.device_call("x", lambda: 3) == 3
    assert tr.device_s == 0.0 and tr.device_phase_s == {}


def test_staleness_histogram_clips_and_folds():
    h = staleness_histogram(np.array([0, 1, 1, 99, -5]), cap=4)
    assert h == [2, 2, 0, 0, 1] and sum(h) == 5


# ---------------------------------------------------------------------------
# read-only contract: goldens bitwise unchanged with tracing fully ON
# ---------------------------------------------------------------------------

def test_static_golden_trajectory_with_tracing_enabled(tmp_path):
    """The pre-refactor golden of test_driver.py, run with device-timing
    tracing AND JSONL recording enabled — bitwise identical times/Π."""
    tr = Tracer(device=True)
    res = run_simulation(_cfg(), _MODEL, _clients(), algorithm="perfed",
                         mode="semi", max_rounds=6, eval_every=2, seed=0,
                         tracer=tr, trace_dir=str(tmp_path))
    assert [float(t).hex() for t in res.times] == [
        "0x0.0p+0", "0x1.b877293c2d615p-1",
        "0x1.ae97a23acc733p+0", "0x1.4066315c4298cp+1"]
    assert float(res.total_time).hex() == "0x1.4066315c4298cp+1"
    assert res.pi.tolist() == [
        [1, 0, 0, 1, 0, 0, 0, 1], [0, 0, 1, 0, 0, 1, 1, 0],
        [0, 1, 0, 0, 1, 0, 0, 1], [1, 0, 1, 1, 0, 0, 0, 0],
        [0, 0, 0, 0, 0, 1, 1, 1], [0, 1, 1, 0, 1, 0, 0, 0]]
    assert res.payload_dispatches == 8
    assert res.payloads_computed == 18
    np.testing.assert_allclose(res.losses, [
        2.3583488166332245, 1.8240666687488556,
        1.4705257415771484, 1.1463348343968391], rtol=1e-6)
    # telemetry attached and coherent
    t = res.telemetry
    assert t is not None and t["schema"] == SCHEMA
    assert t["rounds"] == 6 and t["arrivals"] == 18
    assert t["counts"]["driver.rounds_fused"] == 6


def test_mobile_traced_equals_untraced_bitwise(tmp_path):
    """Mobile multi-cell hierarchy run: tracing must not perturb the
    trajectory (fresh clients per run — their samplers carry RNG state)."""
    cfg = _mobile_cfg()
    kw = dict(algorithm="perfed", mode="semi", bandwidth_policy="equal",
              max_rounds=5, eval_every=2, seed=0)
    r0 = run_simulation(cfg, _MODEL, _clients(24, seed=1), **kw)
    r1 = run_simulation(cfg, _MODEL, _clients(24, seed=1),
                        tracer=Tracer(device=True),
                        trace_dir=str(tmp_path), **kw)
    assert np.array_equal(r0.times, r1.times)
    assert np.array_equal(r0.losses, r1.losses)
    assert np.array_equal(r0.pi, r1.pi)
    assert r0.handovers == r1.handovers
    assert r0.payload_dispatches == r1.payload_dispatches
    assert r1.telemetry is not None
    assert r0.telemetry is None        # untraced → no telemetry


# ---------------------------------------------------------------------------
# JSONL schema round-trip + per-round invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    td = tmp_path_factory.mktemp("trace")
    tr = Tracer(device=True)
    res = run_simulation(_mobile_cfg(), _MODEL, _clients(24, seed=1),
                         algorithm="perfed", mode="semi",
                         bandwidth_policy="equal", max_rounds=5,
                         eval_every=2, seed=0, tracer=tr,
                         trace_dir=str(td))
    return res, read_metrics(res.telemetry["trace_path"])


def test_trace_jsonl_schema_roundtrip(traced_run):
    res, rows = traced_run
    meta, recs, summary = split_rows(rows)
    assert meta["schema"] == SCHEMA and meta["n_ues"] == 24
    assert len(recs) == res.telemetry["rounds"] == 5
    for r in recs:
        for k in REQUIRED_KEYS:
            assert k in r
    assert summary["arrivals"] == sum(r["a"] for r in recs)
    assert validate_rows(rows) == []


def test_trace_per_round_invariants(traced_run):
    res, rows = traced_run
    _, recs, summary = split_rows(rows)
    for r in recs:
        # phase seconds (exclusive) can never exceed the round's wall
        assert sum(r["phase_s"].values()) <= r["wall_s"] * 1.05 + 1e-6
        assert r["device_s"] <= r["wall_s"] * 1.05 + 1e-6
        # A_c equals the arrived-UE set consumed by that round
        assert r["a"] == len(r["ues"]) >= 1
        assert sum(r["staleness_hist"]) >= r["a"]
    # summary totals match SimResult counters
    assert summary["handovers"] == res.handovers
    assert summary["cloud_rounds"] == res.cloud_rounds
    per_cell = {int(c): a for c, a in summary["per_cell_a"].items()}
    assert sum(per_cell.values()) == summary["arrivals"]


def test_validate_rows_catches_corruption(traced_run):
    _, rows = traced_run
    import copy
    bad = copy.deepcopy(rows)
    del bad[0]["_meta"]["schema"]
    assert any("schema" in e for e in validate_rows(bad))
    bad = copy.deepcopy(rows)
    bad[1]["a"] = bad[1]["a"] + 1
    assert any("inconsistent" in e for e in validate_rows(bad))
    bad = copy.deepcopy(rows)
    bad[1]["phase_s"] = {"drain": bad[1]["wall_s"] * 10}
    assert any("exceed" in e for e in validate_rows(bad))
    assert validate_rows([]) != []


def test_trace_report_renders_and_checks(traced_run, capsys):
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "scripts"))
    try:
        from trace_report import main, render
    finally:
        sys.path.pop(0)
    res, rows = traced_run
    text = render(rows)
    assert "phase breakdown" in text and "rounds=5" in text
    assert main([res.telemetry["trace_path"], "--check"]) == 0
    out = capsys.readouterr().out
    assert "OK" in out


# ---------------------------------------------------------------------------
# config plumbing + reporter
# ---------------------------------------------------------------------------

def test_cfg_obs_enables_tracing(tmp_path):
    cfg = dataclasses.replace(
        _cfg(), obs=dataclasses.replace(
            _cfg().obs, trace=True, trace_dir=str(tmp_path)))
    res = run_simulation(cfg, _MODEL, _clients(), max_rounds=3,
                         eval_every=0, seed=0)
    assert res.telemetry is not None
    assert validate_rows(read_metrics(res.telemetry["trace_path"])) == []


def test_reporter_levels_and_verbose_compat():
    out = io.StringIO()
    rep = Reporter("quiet", stream=out)
    rep.progress("p")
    rep.debug("d")
    assert out.getvalue() == ""
    out = io.StringIO()
    rep = Reporter("progress", stream=out)
    rep.progress("p")
    rep.debug("d")
    assert out.getvalue() == "p\n"
    out = io.StringIO()
    rep = Reporter("debug", stream=out)
    rep.progress("p")
    rep.debug("d")
    assert out.getvalue() == "p\nd\n"
    with pytest.raises(ValueError):
        Reporter("loud")


def test_verbose_progress_line_format_unchanged(capsys):
    """verbose=True must keep emitting the exact pre-telemetry line."""
    run_simulation(_cfg(), _MODEL, _clients(), algorithm="perfed",
                   mode="semi", max_rounds=2, eval_every=2, seed=0,
                   verbose=True)
    out = capsys.readouterr().out
    assert "[perfed-semi] round    2 t=" in out
    assert "ploss=" in out and "gloss=" in out


def test_noop_tracer_type_importable():
    assert isinstance(NOOP, NoopTracer)
