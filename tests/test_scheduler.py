"""Algorithm 2 greedy scheduler + Eq. (42)/(43) — property-based."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.config import FLConfig
from repro.core.scheduler import (estimate_A_K, greedy_schedule,
                                  relative_frequencies, schedule_period,
                                  schedule_staleness)
from repro.core.server import SemiSyncServer, ServerConfig


@st.composite
def eta_and_A(draw):
    n = draw(st.integers(3, 24))
    a = draw(st.integers(1, n))
    k = draw(st.integers(1, 60))
    raw = draw(st.lists(st.floats(0.05, 1.0), min_size=n, max_size=n))
    eta = np.array(raw) / np.sum(raw)
    return eta, a, k


@given(eta_and_A())
@settings(max_examples=60, deadline=None)
def test_rows_sum_to_A(case):
    eta, a, k = case
    pi = greedy_schedule(eta, a, k)
    assert pi.shape == (k, len(eta))
    assert (pi.sum(axis=1) == a).all()            # Eq. (14)
    assert ((pi == 0) | (pi == 1)).all()


@given(eta_and_A())
@settings(max_examples=30, deadline=None)
def test_realised_eta_tracks_target(case):
    eta, a, _ = case
    k = 400
    pi = greedy_schedule(eta, a, k)
    realised = pi.sum(0) / (a * k)                # Eq. (15)
    # a UE can participate at most once per round → realised ≤ 1/A; within
    # that ceiling the greedy must track η (tiny-η UEs are floored by the
    # "always schedule A per round" constraint, hence the tolerance)
    tol = 0.05 + 1.0 / k
    assert np.all(realised >= np.minimum(eta, 1.0 / a) - tol)


def test_equal_eta_is_round_robin_periodic():
    eta = relative_frequencies(6, "equal")
    pi = greedy_schedule(eta, 2, 12)
    period = schedule_period(pi)
    assert period <= 3                            # n/A = 3 (Theorem 3)
    assert (pi.sum(0) == 4).all()                 # perfectly balanced


def test_staleness_respects_period():
    eta = relative_frequencies(4, "equal")
    pi = greedy_schedule(eta, 2, 20)
    tau = schedule_staleness(pi)
    assert tau.max() <= 2                         # everyone runs every n/A=2


def test_distance_eta_monotone():
    d = np.array([10.0, 50.0, 100.0, 190.0])
    eta = relative_frequencies(4, "distance", distances=d)
    assert abs(eta.sum() - 1) < 1e-9
    assert (np.diff(eta) < 0).all()               # farther → smaller η


def test_estimate_A_K_bounds():
    fl = FLConfig(beta=0.07, staleness_bound=5)
    eta = relative_frequencies(20, "equal")
    a, k = estimate_A_K(fl, eta=eta, epsilon=0.1, L_F=4.0, sigma_F2=1.0,
                        gamma_F2=1.0)
    assert 1 <= a <= 20
    assert k >= 1
    # smaller epsilon → more rounds required
    _, k2 = estimate_A_K(fl, eta=eta, epsilon=0.01, L_F=4.0, sigma_F2=1.0,
                         gamma_F2=1.0)
    assert k2 >= k


@given(st.integers(2, 30), st.integers(1, 10))
@settings(max_examples=30, deadline=None)
def test_every_ue_eventually_scheduled(n, a):
    a = min(a, n)
    eta = relative_frequencies(n, "equal")
    pi = greedy_schedule(eta, a, 4 * n)
    assert (pi.sum(0) > 0).all()


@st.composite
def feasible_eta_and_A(draw):
    """η with every η_i ≤ 1/A (a UE participates at most once per round,
    so only such targets are attainable): raw weights in [0.5, 1.5] give
    η_i ≤ 3/n, and A ≤ n/3 gives 1/A ≥ 3/n."""
    n = draw(st.integers(6, 24))
    a = draw(st.integers(1, n // 3))
    raw = draw(st.lists(st.floats(0.5, 1.5), min_size=n, max_size=n))
    eta = np.array(raw) / np.sum(raw)
    return eta, a


@given(feasible_eta_and_A())
@settings(max_examples=30, deadline=None)
def test_realised_eta_converges_to_feasible_target(case):
    """Algorithm 2's whole point (Eq. 15): over a long horizon the realised
    participation frequencies converge to the feasible target η."""
    eta, a = case
    k = 500
    pi = greedy_schedule(eta, a, k)
    realised = pi.sum(0) / (a * k)
    assert np.max(np.abs(realised - eta)) < 2.0 / k + 1e-9


def test_schedule_staleness_matches_server_staleness():
    """``schedule_staleness(Π)`` must agree with what ``SemiSyncServer``
    actually tracks when the schedule is replayed through the protocol."""
    eta = relative_frequencies(6, "equal")
    pi = greedy_schedule(eta, 2, 12)
    tau = schedule_staleness(pi)
    payload = {"w": np.zeros(3, np.float32)}
    srv = SemiSyncServer(payload, ServerConfig(
        n_ues=6, participants_per_round=2, staleness_bound=10 ** 6,
        beta=0.1))
    for k in range(pi.shape[0]):
        assert srv.round == k
        scheduled = np.nonzero(pi[k])[0]
        for i in scheduled:
            assert srv.staleness(int(i)) == tau[k, i]
        for i in scheduled:
            srv.on_arrival(int(i), payload)
