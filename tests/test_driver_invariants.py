"""Driver invariants under randomized handover schedules (hypothesis/shim).

PR 3 fixed two real bugs the static/mobile loop duplication had bred:
arrivals mis-routed to a UE's post-handover cell, and mid-drain handovers
skewing per-cell round accounting.  This suite pins those invariants under
the NEW dynamics this PR adds — load-aware association, heterogeneous
per-cell budgets, and the in-loop Theorem-2 allocator — by instrumenting a
``MobileAdapter`` and running real mobile hierarchy simulations across
randomized speeds, cell counts, budget mixes, and seeds:

* every arrival is fed to the cell that DISPATCHED its cycle (the cell
  stamped on the heap event), never the UE's current cell;
* departed arrivals exactly match the hierarchy's own count, and total
  arrivals conserve: closed-round consumption + still-pending uploads;
* per-cell drain targets (``need``) never go non-positive — the server can
  always absorb one more upload before its round closes.
"""
import numpy as np

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import (HealthCheck, given, settings,
                                          strategies as st)

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          ScenarioConfig)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.driver import run_event_loop
from repro.fl.mobile import MobileAdapter
from repro.models import build_model

_DATA = synthetic_mnist(n=900, seed=21)
_MODEL = build_model(get_config("mnist_dnn"))
N_UES = 10


class InstrumentedAdapter(MobileAdapter):
    """Records dispatch stamps, arrival routing, and drain targets.

    ``dispatch_cell`` is called by the driver when (and only when) it can
    stamp a heap event for that UE's next cycle — a cancelled event never
    reaches ``on_arrival``, so at arrival time the last recorded stamp for
    the UE is exactly the cell its arriving cycle was dispatched from.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.stamped: dict = {}
        self.n_arrivals = 0
        self.departed_seen = 0
        self.min_need = 1 << 30

    def dispatch_cell(self, ue: int) -> int:
        c = super().dispatch_cell(ue)
        self.stamped[int(ue)] = c
        return c

    def dispatch_cells(self, ues) -> np.ndarray:
        # the vectorized stamping path (fills / requeues / redistribute)
        cells = super().dispatch_cells(ues)
        for u, c in zip(np.asarray(ues, dtype=np.int64), cells):
            self.stamped[int(u)] = int(c)
        return cells

    def need(self, cell: int) -> int:
        v = super().need(cell)
        self.min_need = min(self.min_need, v)
        return v

    def _record(self, cell: int, ue: int) -> None:
        assert self.stamped.get(int(ue)) == cell, \
            f"arrival of UE {ue} routed to cell {cell}, " \
            f"dispatched from {self.stamped.get(int(ue))}"
        self.n_arrivals += 1
        if self.hier is not None and int(self.hier.member_cell[ue]) != cell:
            self.departed_seen += 1

    def on_arrival(self, cell, ue, payload):
        self._record(cell, int(ue))
        return super().on_arrival(cell, ue, payload)

    def on_round_batch(self, cell, ues, aggregate_fn):
        for u in ues:
            self._record(cell, int(u))
        return super().on_round_batch(cell, ues, aggregate_fn)

    def on_arrival_batch(self, cells, ues, payloads):
        # nothing between a drain's arrivals moves cell membership, so
        # recording all lanes up front matches the per-arrival semantics
        for c, u in zip(cells, ues):
            self._record(int(c), int(u))
        return super().on_arrival_batch(cells, ues, payloads)


def _budgets(mix: str, n_cells: int):
    return {"uniform": (),
            "scalar": (7e5,),
            "macro_micro": (2e6,) + (5e5,) * (n_cells - 1)}[mix]


def _run(seed: int, speed: float, n_cells: int, mix: str,
         bandwidth_policy: str, rounds: int = 5):
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=N_UES, participants_per_round=4, staleness_bound=5,
                    alpha=0.03, beta=0.07, inner_batch=4, outer_batch=4,
                    hessian_batch=4, first_order=True, eta_mode="distance"),
        mobility=MobilityConfig(
            enabled=True, model="random_waypoint", speed_mps=speed,
            n_cells=n_cells, hierarchy=True, cell_participants=2,
            cloud_sync_every=3, cell_bandwidth_hz=_budgets(mix, n_cells),
            association="load_aware",
            # these tiny sims last ~1 simulated second; integration ticks
            # live on the step_s grid, so a sub-second tick keeps the UEs
            # moving (and handovers exercised) within the run
            step_s=0.1))
    clients = partition_noniid(_DATA, N_UES, n_labels=4, seed=seed)
    adapter = InstrumentedAdapter(cfg, N_UES, seed=seed,
                                  bandwidth_policy=bandwidth_policy,
                                  mode="semi")
    res = run_event_loop(cfg, _MODEL, clients, adapter, algorithm="perfed",
                         mode="semi", max_rounds=rounds, eval_every=0,
                         seed=seed)
    return adapter, res


def _check_invariants(adapter: InstrumentedAdapter, res) -> None:
    hier = adapter.hier
    # routing: asserted inline per arrival; departed accounting must agree
    # with the hierarchy's own departed-UE branch exactly
    assert adapter.departed_seen == hier.departed_arrivals
    assert res.departed_arrivals == hier.departed_arrivals
    # conservation: every fed arrival was either consumed by a closed round
    # (each closed round consumes exactly its cell's A) or is still pending
    consumed = sum(srv.a * len(srv.history_pi) for srv in hier.cells)
    pending = sum(len(srv._pending) + srv._seg_n for srv in hier.cells)
    assert adapter.n_arrivals == consumed + pending
    # drain targets never hit zero or below: the server can always absorb
    # one more upload before its round closes
    assert adapter.min_need >= 1
    # realised rounds respect Eq. (14) per cell: each Π row sums to the
    # closing cell's A
    for row, cell in zip(hier.history_pi, hier.history_cell):
        assert row.sum() == hier.cells[cell].a


@given(st.integers(0, 5), st.sampled_from([15.0, 45.0, 90.0]),
       st.integers(2, 3), st.sampled_from(["uniform", "scalar",
                                           "macro_micro"]))
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_invariants_under_random_handover_schedules(seed, speed, n_cells,
                                                    mix):
    adapter, res = _run(seed, speed, n_cells, mix, "equal")
    _check_invariants(adapter, res)


@given(st.integers(0, 3), st.sampled_from([30.0, 80.0]))
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_invariants_hold_under_theorem2_policy(seed, speed):
    """The in-loop Theorem-2 allocator must not perturb the protocol
    invariants (it only rewrites ``adapter.bw`` inside ``pre_requeue``)."""
    adapter, res = _run(seed, speed, 3, "macro_micro", "theorem2")
    _check_invariants(adapter, res)


def test_handovers_actually_exercised():
    """At vehicular speed with 3 cells at least one randomized config must
    produce handovers — otherwise the suite above pins nothing."""
    total = 0
    for seed in range(4):
        adapter, res = _run(seed, 90.0, 3, "macro_micro", "equal", rounds=6)
        _check_invariants(adapter, res)
        total += res.handovers
    assert total >= 1


# ---------------------------------------------------------------------------
# open-world churn lifecycle invariants (randomized join/leave traces)
# ---------------------------------------------------------------------------

class ChurnAdapter(InstrumentedAdapter):
    """Adds UE-lifecycle checks: no distribution may resurrect a departed
    UE (the mask is exact at close time — every applied event predates the
    closing pop), and arrivals from departed UEs are bounded by the number
    of departures.  The bound exists because an upload that finished
    BEFORE its UE left (same drain, earlier simulated time) legitimately
    feeds after the leave flipped the mask; each departure strands at most
    one such in-flight upload, so a zombie UE that keeps computing after
    leaving (e.g. via a mid-flight handover restart) blows the bound."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.resurrections: list = []
        self.ghost_arrivals: list = []

    def _record(self, cell: int, ue: int) -> None:
        super()._record(cell, ue)
        if self._active_mask is not None and not self._active_mask[ue]:
            self.ghost_arrivals.append(ue)

    def _check_distribute(self, res):
        if res is not None and self._active_mask is not None:
            for u in res["distribute"]:
                if not self._active_mask[u]:
                    self.resurrections.append(("distribute", int(u)))
        return res

    def on_arrival(self, cell, ue, payload):
        return self._check_distribute(super().on_arrival(cell, ue, payload))

    def on_arrival_batch(self, cells, ues, payloads):
        return self._check_distribute(
            super().on_arrival_batch(cells, ues, payloads))

    def on_round_batch(self, cell, ues, aggregate_fn):
        return self._check_distribute(
            super().on_round_batch(cell, ues, aggregate_fn))

    def flush_ready(self):
        return [self._check_distribute(r) for r in super().flush_ready()]


def _run_churn(seed: int, arrival: float, departure: float,
               rounds: int = 6):
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=N_UES, participants_per_round=4, staleness_bound=5,
                    alpha=0.03, beta=0.07, inner_batch=4, outer_batch=4,
                    hessian_batch=4, first_order=True, eta_mode="distance"),
        mobility=MobilityConfig(
            enabled=True, model="random_waypoint", speed_mps=30.0,
            n_cells=3, hierarchy=True, cell_participants=2,
            cloud_sync_every=3, step_s=0.1),
        scenario=ScenarioConfig(
            enabled=True, initial_active_frac=0.8,
            arrival_rate=arrival, departure_rate=departure,
            min_active=2, horizon_s=50.0))
    clients = partition_noniid(_DATA, N_UES, n_labels=4, seed=seed)
    adapter = ChurnAdapter(cfg, N_UES, seed=seed,
                           bandwidth_policy="equal", mode="semi")
    res = run_event_loop(cfg, _MODEL, clients, adapter, algorithm="perfed",
                         mode="semi", max_rounds=rounds, eval_every=0,
                         seed=seed)
    return adapter, res


def _check_churn_invariants(adapter: ChurnAdapter, res) -> None:
    hier = adapter.hier
    # no resurrection: every distribution target was alive at close time,
    # and departed-UE arrivals (uploads that finished before the leave in
    # the same drain) never exceed one per departure
    assert adapter.resurrections == []
    assert len(adapter.ghost_arrivals) <= res.ue_departures
    # arrival conservation under churn: every fed arrival was consumed by
    # a closed round (Π row sums count the ACTUAL arrivals of clamped
    # rounds, not the nominal A) or is still pending at exit
    consumed = sum(int(r.sum()) for r in hier.history_pi)
    assert adapter.n_arrivals == consumed + hier.pending_uploads()
    assert res.pending_uploads == (hier.pending_uploads()
                                   if res.aborted_rounds else
                                   res.pending_uploads)
    # clamped rounds stay within [1, nominal A] per cell
    for row, cell in zip(hier.history_pi, hier.history_cell):
        assert 1 <= int(row.sum()) <= hier.cells[cell].a
    # drain targets stayed positive (flush closes met-target rounds
    # before any drain starts)
    assert adapter.min_need >= 1
    # churn counters surface on the result
    assert res.ue_joins >= 0 and res.ue_departures >= 0
    assert 0.0 <= res.wait_fraction <= 1.0


@given(st.integers(0, 5),
       st.sampled_from([0.0, 1.0, 4.0]),       # joins / sim-s
       st.sampled_from([0.2, 1.0]))            # per-UE departure hazard
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_lifecycle_invariants_under_random_churn_traces(seed, arrival,
                                                        departure):
    adapter, res = _run_churn(seed, arrival, departure)
    _check_churn_invariants(adapter, res)


def test_churn_actually_exercised():
    """The randomized sweep must include traces with real joins AND real
    departures — otherwise the lifecycle invariants above pin nothing."""
    joins = departures = 0
    for seed in range(3):
        adapter, res = _run_churn(seed, 4.0, 0.5)
        _check_churn_invariants(adapter, res)
        joins += res.ue_joins
        departures += res.ue_departures
    assert joins >= 1 and departures >= 1
