"""Algorithm 1 server semantics — including the paper's Fig. 1 example."""
import numpy as np
import pytest

from repro.core.server import SemiSyncServer, ServerConfig


def _payload(v=1.0):
    return {"w": np.array([v], dtype=np.float32)}


def _mk(n=4, a=2, s=5, beta=0.1, mode="semi"):
    return SemiSyncServer(_payload(0.0), ServerConfig(
        n_ues=n, participants_per_round=a, staleness_bound=s, beta=beta,
        mode=mode))


def test_round_advances_on_A_arrivals():
    srv = _mk(a=2)
    assert srv.on_arrival(0, _payload()) is None
    res = srv.on_arrival(1, _payload())
    assert res is not None and res["round"] == 1
    assert 0 in res["distribute"] and 1 in res["distribute"]


def test_eq8_update_value():
    srv = _mk(a=2, beta=0.1)
    srv.on_arrival(0, _payload(2.0))
    res = srv.on_arrival(1, _payload(4.0))
    # w = 0 − 0.1/2 · (2+4) = −0.3
    assert abs(float(res["params"]["w"][0]) + 0.3) < 1e-6


def test_fig1_example_schedule():
    """Fig. 1: 4 UEs, A=2.  UEs 1,2 fast; 3,4 stragglers whose gradients land
    in rounds 2 and 3.  Reproduce the Π matrix of Eq. (13) (0-indexed UEs).

    Arrival order: (u0,u1) → round1; (u2, u0') → round2; (u3, u1') → round3;
    then the pattern repeats: (u2', u0'') wait — we just check the first
    3 rounds match Eq. (13)'s first 3 rows: [1,1,0,0], [0,1,1,0]→ our order
    [(u1,u2)], [1,0,0,1].
    """
    srv = _mk(n=4, a=2, s=10)
    # round 1: UEs 0 and 1 arrive first
    srv.on_arrival(0, _payload())
    srv.on_arrival(1, _payload())
    # round 2: straggler u2's stale grad + fast u1 again
    srv.on_arrival(1, _payload())
    srv.on_arrival(2, _payload())
    # round 3: straggler u3 + fast u0
    srv.on_arrival(0, _payload())
    srv.on_arrival(3, _payload())
    pi = srv.pi_matrix()
    want = np.array([[1, 1, 0, 0],
                     [0, 1, 1, 0],
                     [1, 0, 0, 1]])
    assert np.array_equal(pi, want), pi


def test_row_sums_equal_A():
    srv = _mk(n=6, a=3)
    order = [0, 1, 2, 3, 4, 5, 0, 2, 4]
    for u in order:
        srv.on_arrival(u, _payload())
    pi = srv.pi_matrix()
    assert pi.shape == (3, 6)
    assert (pi.sum(1) == 3).all()


def test_stale_ues_get_redistributed():
    srv = _mk(n=4, a=2, s=1)
    # UEs 2,3 never upload; after τ > S=1 they must appear in distribute
    srv.on_arrival(0, _payload())
    r1 = srv.on_arrival(1, _payload())
    assert set(r1["distribute"]) == {0, 1}          # τ(2)=1 not yet > 1
    srv.on_arrival(0, _payload())
    r2 = srv.on_arrival(1, _payload())
    assert {2, 3} <= set(r2["distribute"])          # τ = 2 > S


def test_staleness_definition():
    srv = _mk(n=3, a=1, s=10)
    srv.on_arrival(0, _payload())      # round 1; only u0 refreshed
    srv.on_arrival(0, _payload())      # round 2
    assert srv.staleness(0) == 0
    assert srv.staleness(1) == 2
    assert srv.staleness(2) == 2


def test_sync_mode_waits_for_all():
    srv = _mk(n=4, a=2, mode="sync")
    for u in (0, 1, 2):
        assert srv.on_arrival(u, _payload()) is None
    assert srv.on_arrival(3, _payload())["round"] == 1


def test_async_mode_updates_every_arrival():
    srv = _mk(n=4, mode="async")
    for k, u in enumerate([2, 0, 3]):
        res = srv.on_arrival(u, _payload())
        assert res is not None and res["round"] == k + 1


def test_realised_eta_sums_to_one():
    srv = _mk(n=5, a=2)
    rng = np.random.default_rng(0)
    for _ in range(40):
        srv.on_arrival(int(rng.integers(5)), _payload())
    eta = srv.realised_eta()
    assert abs(eta.sum() - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# batch-wise segment feed (on_arrival_batch)
# ---------------------------------------------------------------------------

def _stacked(vals):
    """Stacked payload tree: leading lane axis, arrival order."""
    return {"w": np.asarray([[v] for v in vals], dtype=np.float32)}


def test_batch_feed_matches_per_arrival():
    a_srv = _mk(n=4, a=3, beta=0.1)
    b_srv = _mk(n=4, a=3, beta=0.1)
    for u, v in [(0, 2.0), (1, 4.0)]:
        assert a_srv.on_arrival(u, _payload(v)) is None
    ra = a_srv.on_arrival(2, _payload(6.0))
    # same uploads as two segments: a non-closing drain, then the closer
    assert b_srv.on_arrival_batch([0, 1], _stacked([2.0, 4.0])) is None
    assert b_srv.arrivals_until_round() == 1
    rb = b_srv.on_arrival_batch([2], _stacked([6.0]))
    assert ra["round"] == rb["round"] == 1
    assert ra["distribute"] == rb["distribute"]
    np.testing.assert_allclose(np.asarray(rb["params"]["w"]),
                               np.asarray(ra["params"]["w"]), rtol=1e-6)
    np.testing.assert_array_equal(a_srv.pi_matrix(), b_srv.pi_matrix())
    np.testing.assert_array_equal(a_srv.ue_version, b_srv.ue_version)
    np.testing.assert_array_equal(np.stack(a_srv.history_staleness),
                                  np.stack(b_srv.history_staleness))


def test_batch_feed_taus_override_discounted_weights():
    """λ<1: the explicit ``taus`` vector must weight exactly as the same
    staleness read off ``ue_version`` would (the hierarchy snapshots τ
    before reverting transient visiting stamps)."""
    a_srv = _mk(n=4, a=2, beta=0.1)
    b_srv = _mk(n=4, a=2, beta=0.1)
    a_srv.cfg.staleness_discount = b_srv.cfg.staleness_discount = 0.5
    a_srv.ue_version[1] = -2                 # τ(1) = 2 at round 0
    ra = a_srv.on_arrival(0, _payload(2.0)) or a_srv.on_arrival(
        1, _payload(4.0))
    rb = b_srv.on_arrival_batch([0, 1], _stacked([2.0, 4.0]),
                                taus=np.array([0, 2]))
    np.testing.assert_allclose(np.asarray(rb["params"]["w"]),
                               np.asarray(ra["params"]["w"]), rtol=1e-6)


def test_batch_feed_overshoot_raises():
    srv = _mk(n=4, a=2)
    with pytest.raises(RuntimeError, match="overshoots"):
        srv.on_arrival_batch([0, 1, 2], _stacked([1.0, 1.0, 1.0]))


def test_mixed_feed_styles_raise():
    srv = _mk(n=4, a=3)
    srv.on_arrival(0, _payload())
    with pytest.raises(RuntimeError, match="per-arrival uploads pending"):
        srv.on_arrival_batch([1], _stacked([1.0]))
    srv2 = _mk(n=4, a=3)
    srv2.on_arrival_batch([0], _stacked([1.0]))
    with pytest.raises(RuntimeError, match="segment uploads pending"):
        srv2.on_arrival(1, _payload())
    srv3 = _mk(n=4, a=2)
    srv3.on_arrival_batch([0], _stacked([1.0]))
    with pytest.raises(RuntimeError, match="pending uploads"):
        srv3.on_round_batch([0, 1], lambda p, w: p)
