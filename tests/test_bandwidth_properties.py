"""Property suite for the Theorem-2/4 allocators (hypothesis or the shim).

Pins the allocation-level contracts the mobile loop's ``theorem2`` policy
now leans on per requeue:

* ``equal_finish_allocation`` — non-negative, exhausts the budget, truly
  equalises finish times when it reports ``converged``, is monotone in the
  payload size, and its warm-started bisection (``t_hint``) lands on the
  same fixed point as a cold start.
* ``bandwidths_for_time`` — the vectorized Theorem-4 inversion is bitwise
  identical per lane to the scalar ``bandwidth_for_time`` (what makes the
  in-loop bisection affordable at 1024 UEs).
* ``weighted_equal_rate_allocation`` — realised rates proportional to η.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container (tier-1)
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.core.bandwidth import (UEChannel, bandwidth_for_time,
                                  bandwidths_for_time,
                                  equal_finish_allocation, uplink_rate,
                                  weighted_equal_rate_allocation)
from repro.wireless.timing import finish_times

N0 = 10 ** (-174.0 / 10.0) / 1000.0


def _ch(h, d):
    return UEChannel(p=0.01, h=float(h), dist=float(d), kappa=3.8, n0=N0)


@st.composite
def round_inputs(draw, n_min=2, n_max=6):
    """One round's link state: fading, distances, compute times, payloads."""
    n = draw(st.integers(n_min, n_max))
    h = [draw(st.floats(5.0, 150.0)) for _ in range(n)]
    d = [draw(st.floats(20.0, 250.0)) for _ in range(n)]
    tc = [draw(st.floats(0.0, 0.3)) for _ in range(n)]
    z = [draw(st.floats(1e5, 2e6)) for _ in range(n)]
    return h, d, tc, z


# ---------------------------------------------------------------------------
# equal_finish_allocation (Theorem 2)
# ---------------------------------------------------------------------------

@given(round_inputs())
@settings(max_examples=25, deadline=None)
def test_equal_finish_on_simplex_and_equalised(inputs):
    h, d, tc, z = inputs
    chans = [_ch(h[i], d[i]) for i in range(len(h))]
    res = equal_finish_allocation(z, tc, chans, 1e6)
    assert res.converged
    assert np.all(res.b >= 0.0)
    assert np.all(np.isfinite(res.b))
    assert abs(res.b.sum() - 1e6) / 1e6 < 1e-6          # budget exhausted
    fin = finish_times(z, res.b, chans, tc)
    assert np.ptp(fin) < 1e-3 * res.t_star              # Theorem-2 property
    assert abs(np.mean(fin) - res.t_star) < 1e-2 * res.t_star


@given(round_inputs(), st.integers(0, 5), st.floats(1.3, 4.0))
@settings(max_examples=25, deadline=None)
def test_equal_finish_monotone_in_payload(inputs, which, scale):
    """Growing one UE's payload must grow its share of the budget (and the
    common finish time): bandwidth is monotone in z_bits."""
    h, d, tc, z = inputs
    n = len(h)
    chans = [_ch(h[i], d[i]) for i in range(n)]
    base = equal_finish_allocation(z, tc, chans, 1e6)
    i = which % n
    z2 = list(z)
    z2[i] = z[i] * scale
    grown = equal_finish_allocation(z2, tc, chans, 1e6)
    assert base.converged and grown.converged
    assert grown.t_star >= base.t_star * (1.0 - 1e-9)
    assert grown.b[i] >= base.b[i] * (1.0 - 1e-6)


@given(round_inputs(), st.floats(0.7, 1.4))
@settings(max_examples=25, deadline=None)
def test_equal_finish_warm_start_agrees_with_cold(inputs, jitter):
    """The mobile loop warm-starts each cell's bisection from its previous
    t_star; a (possibly stale) hint must land on the same fixed point."""
    h, d, tc, z = inputs
    chans = [_ch(h[i], d[i]) for i in range(len(h))]
    cold = equal_finish_allocation(z, tc, chans, 1e6)
    assert cold.converged
    warm = equal_finish_allocation(z, tc, chans, 1e6,
                                   t_hint=cold.t_star * jitter)
    assert warm.converged
    assert abs(warm.t_star - cold.t_star) < 1e-6 * cold.t_star
    np.testing.assert_allclose(warm.b, cold.b, rtol=1e-5)
    # the degenerate hint keeps the cold-start path bit-for-bit
    again = equal_finish_allocation(z, tc, chans, 1e6, t_hint=None)
    np.testing.assert_array_equal(again.b, cold.b)
    assert again.t_star == cold.t_star


@given(round_inputs())
@settings(max_examples=15, deadline=None)
def test_equal_finish_precomputed_q_path_bitwise(inputs):
    """The mobile loop's realloc passes precomputed SNR numerators instead
    of channel objects — same allocation, to the bit."""
    h, d, tc, z = inputs
    chans = [_ch(h[i], d[i]) for i in range(len(h))]
    via_channels = equal_finish_allocation(z, tc, chans, 1e6)
    via_q = equal_finish_allocation(
        z, tc, None, 1e6, q=np.array([ch.q for ch in chans]))
    np.testing.assert_array_equal(via_channels.b, via_q.b)
    assert via_channels.t_star == via_q.t_star
    assert via_channels.converged == via_q.converged


# ---------------------------------------------------------------------------
# vectorized Theorem-4 inversion ≡ scalar, bitwise
# ---------------------------------------------------------------------------

@given(round_inputs(), st.floats(-0.05, 2.0))
@settings(max_examples=40, deadline=None)
def test_bandwidths_for_time_bitwise_equals_scalar(inputs, t):
    h, d, tc, z = inputs
    n = len(h)
    chans = [_ch(h[i], d[i]) for i in range(n)]
    q = np.array([ch.q for ch in chans])
    want = np.array([bandwidth_for_time(z[i], t, tc[i], chans[i])
                     for i in range(n)])
    got = bandwidths_for_time(np.asarray(z), t, np.asarray(tc), q)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# weighted_equal_rate_allocation (the Theorem-4 other extreme)
# ---------------------------------------------------------------------------

@given(round_inputs(n_min=2, n_max=5))
@settings(max_examples=25, deadline=None)
def test_weighted_equal_rate_proportional_to_eta(inputs):
    h, d, tc, z = inputs
    n = len(h)
    chans = [_ch(h[i], d[i]) for i in range(n)]
    rng = np.random.default_rng(int(1e3 * (sum(h) + sum(d))) % (2 ** 31))
    eta = rng.uniform(0.1, 1.0, n)
    eta = eta / eta.sum()
    b = weighted_equal_rate_allocation(eta, chans, 1e6)
    assert np.all(b > 0.0)
    assert abs(b.sum() - 1e6) / 1e6 < 1e-6
    r = np.array([float(uplink_rate(b[i], chans[i])) for i in range(n)])
    ratios = r / eta
    assert np.ptp(ratios) / ratios.mean() < 5e-2        # r_i ∝ η_i
