"""Multi-device lowering tests.

Device count locks at first jax init, so these run in SUBPROCESSES with
``--xla_force_host_platform_device_count=8`` and small meshes (2,4) /
(2,2,2).  Reduced configs keep compiles fast; the full-size production-mesh
sweep is ``python -m repro.launch.dryrun`` (see EXPERIMENTS.md §Dry-run).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.configs import get_config, get_shape
from repro.config import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.specs import build_case, arch_rules

arch, kind, multipod = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
mesh = make_mesh((2, 2, 2), ("pod", "data", "model")) if multipod \
    else make_mesh((2, 4), ("data", "model"))
cfg = get_config(arch).reduced()
shape = {
    "train":   ShapeConfig("t", seq_len=64, global_batch=8, kind="train"),
    "prefill": ShapeConfig("p", seq_len=128, global_batch=8, kind="prefill"),
    "decode":  ShapeConfig("d", seq_len=128, global_batch=8, kind="decode"),
}[kind]
rules = arch_rules(cfg, mesh)
cohorts = 2 if (multipod and kind == "train") else None
with sharding.use_mesh(mesh, rules):
    case = build_case(cfg, shape, mesh, semi_sync_cohorts=cohorts, rules=rules)
    jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings)
    lowered = jitted.lower(*case.args)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):      # jax < 0.4.35 returned [dict]
    cost = cost[0] if cost else {}
print(json.dumps({"ok": True, "flops": float(cost.get("flops", 0.0))}))
"""


def _run(arch: str, kind: str, mesh: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, kind, mesh],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, f"{arch}/{kind}/{mesh}:\n{out.stderr[-3000:]}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    return rec


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "mixtral_8x22b", "mamba2_370m",
                                  "recurrentgemma_2b"])
def test_single_pod_train_lowers(arch):
    rec = _run(arch, "train", "single")
    assert rec["flops"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_v2_236b"])
def test_multi_pod_semi_sync_train_lowers(arch):
    _run(arch, "train", "multi")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "musicgen_large",
                                  "llama32_vision_11b"])
def test_decode_lowers(arch):
    _run(arch, "decode", "single")


@pytest.mark.slow
def test_prefill_lowers():
    _run("starcoder2_15b", "prefill", "single")
