"""Theorem 1 / Corollary 1 analytic expressions."""

import pytest

from repro.core.convergence import (SmoothnessParams, corollary1_rates,
                                    fosp_bound, gamma_F2, max_feasible_beta,
                                    sigma_F2, smoothness_F, step_condition)


def test_lemma1_smoothness():
    p = SmoothnessParams(L=2.0, C=3.0, rho=0.5)
    assert smoothness_F(p, alpha=0.1) == pytest.approx(4 * 2 + 0.1 * 0.5 * 3)


def test_lemma2_variance_decreases_with_batch():
    p = SmoothnessParams()
    small = sigma_F2(p, 0.05, d_in=4, d_o=4, d_h=4)
    big = sigma_F2(p, 0.05, d_in=64, d_o=64, d_h=64)
    assert big < small
    assert big > 0


def test_lemma3_gamma():
    p = SmoothnessParams(C=2.0, gamma_H=0.5, gamma_G=0.1)
    got = gamma_F2(p, alpha=0.1)
    assert got == pytest.approx(3 * 4 * 0.01 * 0.25 + 192 * 0.01)


def test_step_condition_and_max_beta():
    l_f, s = 4.0, 5
    beta = max_feasible_beta(l_f, s)
    assert step_condition(l_f, beta, s) == pytest.approx(1.0, abs=1e-9)
    assert step_condition(l_f, beta * 0.5, s) < 1.0
    assert step_condition(l_f, beta * 2.0, s) > 1.0


def test_bound_decreases_in_K_increases_in_A():
    kw = dict(loss_gap=1.0, beta=0.01, s=5, l_f=4.0, sig_f2=1.0, gam_f2=1.0)
    b1 = fosp_bound(k=100, a=4, **kw)
    b2 = fosp_bound(k=1000, a=4, **kw)
    b3 = fosp_bound(k=100, a=16, **kw)
    assert b2 < b1          # more rounds → tighter
    assert b3 > b1          # more (stale-capable) participants → looser √A term


def test_bound_increases_with_staleness():
    kw = dict(loss_gap=1.0, beta=0.01, k=100, a=4, l_f=4.0, sig_f2=1.0,
              gam_f2=1.0)
    assert fosp_bound(s=10, **kw) > fosp_bound(s=1, **kw)


def test_corollary1_scalings():
    r = corollary1_rates(0.1)
    assert r["K"] == pytest.approx(1e3)
    assert r["A"] == pytest.approx(1e2)
    assert r["S"] == pytest.approx(1e1)
    assert r["beta"] == pytest.approx(1e-2)
