"""End-to-end behaviour of the whole system (replaces the scaffold stub):
paper pipeline = theory → scheduler/bandwidth → Alg.1 server → convergent
personalized model, plus the launchers' public CLIs."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_full_paper_pipeline():
    from repro.config import ExperimentConfig, FLConfig
    from repro.configs import get_config
    from repro.core.convergence import (SmoothnessParams, gamma_F2,
                                        max_feasible_beta, sigma_F2,
                                        smoothness_F)
    from repro.core.scheduler import estimate_A_K, relative_frequencies
    from repro.data import partition_noniid, synthetic_mnist
    from repro.fl.simulation import run_simulation
    from repro.models import build_model

    # 1) theory → hyperparameters (Corollary 1 / Eq. 42-43)
    p = SmoothnessParams(L=1.0, C=1.0, rho=0.5)
    alpha = 0.03
    l_f = smoothness_F(p, alpha)
    fl = FLConfig(n_ues=10, alpha=alpha, staleness_bound=3,
                  inner_batch=16, outer_batch=16, hessian_batch=16)
    beta = min(fl.beta, max_feasible_beta(l_f, fl.staleness_bound))
    eta = relative_frequencies(10, "equal")
    a_star, k_star = estimate_A_K(
        fl, eta=eta, epsilon=0.5, L_F=l_f,
        sigma_F2=sigma_F2(p, alpha, 16, 16, 16), gamma_F2=gamma_F2(p, alpha))
    assert 1 <= a_star <= 10 and k_star >= 1

    # 2) run the full system with those hyperparameters
    cfg = ExperimentConfig(model=get_config("mnist_dnn"),
                           fl=FLConfig(n_ues=10, participants_per_round=a_star,
                                       staleness_bound=3, alpha=alpha,
                                       beta=float(beta), inner_batch=16,
                                       outer_batch=16, hessian_batch=16))
    model = build_model(cfg.model)
    clients = partition_noniid(synthetic_mnist(n=2000, seed=7), 10, 4, seed=7)
    res = run_simulation(cfg, model, clients, algorithm="perfed", mode="semi",
                         max_rounds=20, eval_every=20, seed=7)
    assert res.losses[-1] < res.losses[0]
    assert (res.pi.sum(1) == a_star).all()


@pytest.mark.slow
def test_train_launcher_fl_mode():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--mode", "fl",
         "--arch", "mnist_dnn", "--algo", "perfed", "--sync-mode", "semi",
         "fl.rounds=10", "fl.n_ues=8", "fl.participants_per_round=3"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "final:" in out.stdout


@pytest.mark.slow
def test_serve_launcher():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi_6b",
         "--batch", "2", "--prompt-len", "16", "--gen", "4", "--personalize"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode:" in out.stdout
