"""Open-world scenario suite: runtime units, lifecycle fixes, bitwise pins.

Covers the churn subsystem this PR adds end to end:

* ``ScenarioRuntime`` unit behaviour — thinning-sampled arrivals, the
  ``min_active`` departure floor, alive-time integration, the
  ``can_spawn`` liveness predicate, and deterministic replay;
* ``ClientDataset.drift_labels`` — label remapping on both splits from
  the caller's (scenario) stream only;
* the UE-lifecycle fixes: the frozen-A cell live-lock (adaptive clamp
  vs legacy behaviour), silent pending-upload loss on heap exhaustion
  (now counted + warned), the ``wait_fraction`` denominator under
  churn, and the stale theorem2 warm-start on an emptied cell;
* bitwise discipline: a zero-rate *enabled* scenario reproduces the
  closed-world run exactly, and churn runs are seed-deterministic;
* the ``benchmarks/scenarios.py`` registry contract.
"""
import dataclasses

import numpy as np
import pytest

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          ScenarioConfig)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.scenario import JOIN, LEAVE, ScenarioRuntime, make_scenario
from repro.fl.simulation import run_simulation
from repro.models import build_model

_DATA = synthetic_mnist(n=640, seed=3)
_MODEL = build_model(get_config("mnist_dnn"))
N_UES = 16


def _clients(n=N_UES, seed=0):
    return partition_noniid(_DATA, n, n_labels=4, seed=seed)


def _cfg(n=N_UES, a=4, *, mobility=None, scenario=None, **fl_kw):
    return ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=n, participants_per_round=a, staleness_bound=4,
                    alpha=0.03, beta=0.07, first_order=True,
                    inner_batch=4, outer_batch=4, hessian_batch=4, **fl_kw),
        mobility=mobility or MobilityConfig(),
        scenario=scenario or ScenarioConfig())


def _run(cfg, clients, *, rounds=4, seed=0, policy="equal", **kw):
    return run_simulation(cfg, _MODEL, clients, algorithm="perfed",
                          mode="semi", bandwidth_policy=policy,
                          max_rounds=rounds, eval_every=0, seed=seed, **kw)


# ---------------------------------------------------------------------------
# ScenarioRuntime units
# ---------------------------------------------------------------------------

def test_disabled_scenario_makes_no_runtime():
    assert make_scenario(ScenarioConfig(), 8, seed=0) is None


def test_initial_active_fraction_and_floor():
    scen = ScenarioRuntime(ScenarioConfig(enabled=True,
                                          initial_active_frac=0.5),
                           10, seed=1)
    assert int(scen.active.sum()) == 5
    # at least one UE active even for a vanishing fraction
    tiny = ScenarioRuntime(ScenarioConfig(enabled=True,
                                          initial_active_frac=0.0),
                           10, seed=1)
    assert int(tiny.active.sum()) == 1


def test_event_stream_is_deterministic():
    cfg = ScenarioConfig(enabled=True, initial_active_frac=0.5,
                         arrival_rate=2.0, departure_rate=0.3,
                         min_active=1, horizon_s=50.0)
    def trace(seed):
        scen = ScenarioRuntime(cfg, 12, seed=seed)
        out = []
        while True:
            ev = scen.next_event(1e9)
            if ev is None:
                return out
            out.append(ev)
    a, b = trace(7), trace(7)
    assert a == b and len(a) > 0
    assert trace(8) != a          # the stream folds the sim seed in


def test_departures_respect_min_active_floor():
    cfg = ScenarioConfig(enabled=True, arrival_rate=0.0,
                         departure_rate=5.0, min_active=3, horizon_s=100.0)
    scen = ScenarioRuntime(cfg, 8, seed=0)
    while scen.next_event(1e9) is not None:
        pass
    assert int(scen.active.sum()) == 3


def test_alive_total_without_churn_is_n_times_t():
    scen = ScenarioRuntime(ScenarioConfig(enabled=True), 6, seed=0)
    t = 12.34567
    assert scen.alive_total(t) == 6 * t          # exactly, not approximately


def test_alive_total_integrates_departures():
    cfg = ScenarioConfig(enabled=True, departure_rate=1.0, min_active=1,
                         horizon_s=100.0)
    scen = ScenarioRuntime(cfg, 6, seed=2)
    ev = scen.next_event(1e9)
    assert ev is not None and ev[1] == LEAVE
    t_leave = ev[0]
    t = t_leave + 5.0
    # the leaver contributes t_leave seconds, the 5 survivors t each
    assert scen.alive_total(t) == pytest.approx(5 * t + t_leave)
    assert scen.alive_total(t) < 6 * t


def test_was_alive_replays_join_leave_history():
    cfg = ScenarioConfig(enabled=True, initial_active_frac=0.5,
                         arrival_rate=3.0, departure_rate=0.5,
                         min_active=1, horizon_s=30.0)
    scen = ScenarioRuntime(cfg, 10, seed=5)
    t0_active = scen.active.copy()
    events = []
    while True:
        ev = scen.next_event(1e9)
        if ev is None:
            break
        events.append(ev)
    joins = [e for e in events if e[1] == JOIN]
    leaves = [e for e in events if e[1] == LEAVE]
    assert joins and leaves
    for ue in range(10):
        assert scen.was_alive(ue, 0.0) == bool(t0_active[ue])
    t, kind, ue = joins[0]
    assert scen.was_alive(ue, t + 1e-9)
    t, kind, ue = leaves[-1]
    assert not scen.was_alive(ue, t + 1e-9) or any(
        te > t and k == JOIN and u == ue for te, k, u in events)


def test_can_spawn_dies_with_the_arrival_stream():
    # no arrivals ever → a dry heap can never refill
    scen = ScenarioRuntime(ScenarioConfig(enabled=True, departure_rate=1.0),
                           4, seed=0)
    assert not scen.can_spawn()
    # live arrivals, dormant pool available → can spawn
    scen2 = ScenarioRuntime(ScenarioConfig(enabled=True,
                                           initial_active_frac=0.5,
                                           arrival_rate=1.0), 4, seed=0)
    assert scen2.can_spawn()
    # full pool, no departures → a join can never find a dormant UE
    scen3 = ScenarioRuntime(ScenarioConfig(enabled=True, arrival_rate=1.0),
                            4, seed=0)
    assert not scen3.can_spawn()
    # full pool but departures can free a slot (floor permitting)
    scen4 = ScenarioRuntime(ScenarioConfig(enabled=True, arrival_rate=1.0,
                                           departure_rate=1.0,
                                           min_active=1), 4, seed=0)
    assert scen4.can_spawn()


def test_diurnal_intensity_and_flash_boost():
    cfg = ScenarioConfig(enabled=True, arrival_rate=1.0,
                         diurnal_amplitude=0.5, diurnal_period_s=4.0,
                         flash_time_s=10.0, flash_duration_s=1.0,
                         flash_arrival_boost=3.0)
    scen = ScenarioRuntime(cfg, 4, seed=0)
    assert scen.arrival_intensity(1.0) == pytest.approx(1.5)   # crest
    assert scen.arrival_intensity(3.0) == pytest.approx(0.5)   # trough
    assert scen.arrival_intensity(10.5) == pytest.approx(
        3.0 * (1.0 + 0.5 * np.sin(2 * np.pi * 10.5 / 4.0)))
    assert scen.arrival_intensity(11.5) == pytest.approx(
        1.0 + 0.5 * np.sin(2 * np.pi * 11.5 / 4.0))            # window shut


def test_scenario_config_validation():
    with pytest.raises(ValueError):
        ScenarioRuntime(ScenarioConfig(enabled=True, diurnal_amplitude=1.5),
                        4, seed=0)
    with pytest.raises(ValueError):
        ScenarioRuntime(ScenarioConfig(enabled=True,
                                       flash_arrival_boost=-1.0), 4, seed=0)


# ---------------------------------------------------------------------------
# label drift
# ---------------------------------------------------------------------------

def test_drift_labels_remaps_both_splits_from_caller_stream():
    c = _clients(n=4, seed=0)[0]
    rng = np.random.default_rng(123)
    y_tr, y_te = c.data["y"].copy(), c.test["y"].copy()
    before = c.rng.bit_generator.state
    changed = c.drift_labels(rng, frac=1.0)
    assert changed > 0
    # a full-frac drift remaps through one permutation: the multiset of
    # (old, new) pairs is a function old → new on both splits
    lut = {}
    for old, new in zip(np.concatenate([y_tr, y_te]),
                        np.concatenate([c.data["y"], c.test["y"]])):
        assert lut.setdefault(int(old), int(new)) == int(new)
    assert any(k != v for k, v in lut.items())
    assert set(np.unique(c.data["y"])) <= set(int(v) for v in c.labels_held)
    # the client's private sampler stream must be untouched
    assert c.rng.bit_generator.state == before


def test_drift_labels_zero_frac_changes_nothing():
    c = _clients(n=4, seed=0)[1]
    y = c.data["y"].copy()
    assert c.drift_labels(np.random.default_rng(0), frac=0.0) == 0
    np.testing.assert_array_equal(c.data["y"], y)


# ---------------------------------------------------------------------------
# lifecycle fixes in the driver
# ---------------------------------------------------------------------------

_HIER = MobilityConfig(enabled=True, model="random_waypoint",
                       speed_mps=10.0, n_cells=3, hierarchy=True,
                       cell_participants=3, cloud_sync_every=3, step_s=0.2)

# departures only: the population decays toward min_active, dropping
# cells below their (frozen) A — the live-lock regime
_DRAIN_CHURN = ScenarioConfig(enabled=True, arrival_rate=0.0,
                              departure_rate=1.5, min_active=4,
                              horizon_s=100.0)


def test_adaptive_clamp_fixes_cell_starvation_livelock():
    """With the legacy frozen per-cell A (``adaptive_cell_a=False``) a
    churn-shrunken cell can never close its round again: the run exhausts
    its heap early and aborts with pending uploads.  The adaptive live-
    membership clamp keeps every cell closable and the run completes."""
    clients = _clients()
    rounds = 8
    legacy = _run(_cfg(mobility=_HIER, scenario=dataclasses.replace(
        _DRAIN_CHURN, adaptive_cell_a=False)), clients, rounds=rounds)
    assert legacy.pi.shape[0] < rounds          # starved before the target
    assert legacy.aborted_rounds > 0
    assert legacy.pending_uploads > 0

    fixed = _run(_cfg(mobility=_HIER, scenario=_DRAIN_CHURN), clients,
                 rounds=rounds)
    assert fixed.pi.shape[0] == rounds          # same churn, full run
    assert fixed.aborted_rounds == 0
    assert fixed.ue_departures > 0


def test_heap_exhaustion_counts_aborted_round_and_warns(capsys):
    """A > n can never close a round: the heap drains silently.  That
    used to lose the pending uploads without a trace — now it is counted
    on the result and warned at every report level."""
    clients = _clients(n=3)
    res = _run(_cfg(n=3, a=5), clients, rounds=2)
    assert res.pi.shape[0] == 0
    assert res.aborted_rounds == 1
    assert res.pending_uploads == 3
    assert "WARNING" in capsys.readouterr().out


def test_wait_fraction_uses_alive_time_under_churn():
    """Departed UEs must not be charged their whole absence as idle: the
    denominator integrates per-UE alive time, keeping the fraction a
    fraction."""
    clients = _clients()
    res = _run(_cfg(mobility=_HIER, scenario=_DRAIN_CHURN), clients,
               rounds=8)
    assert res.ue_departures > 0
    assert 0.0 <= res.wait_fraction <= 1.0


def test_empty_cell_resets_theorem2_warm_start():
    from repro.fl.mobile import MobileAdapter
    cfg = _cfg(mobility=_HIER)
    adapter = MobileAdapter(cfg, N_UES, seed=0,
                            bandwidth_policy="theorem2", mode="semi")
    adapter.net.active = np.zeros(N_UES, dtype=bool)   # cell 0 emptied
    adapter._t_star[0] = 3.21
    adapter._realloc(0)
    # the stale equal-finish hint is dropped, not kept for the next
    # population of the cell
    assert adapter._t_star[0] == 0.0


# ---------------------------------------------------------------------------
# bitwise discipline
# ---------------------------------------------------------------------------

def _fingerprint(res):
    return (res.pi.tobytes(), float(res.total_time),
            res.eta_realised.tobytes(), float(res.wait_fraction))


def test_zero_rate_enabled_scenario_is_bitwise_closed_world():
    """Turning the scenario machinery ON with all rates at zero must
    reproduce the closed-world trajectory bit for bit — the scenario
    stream is auxiliary and never perturbs the simulator's RNG."""
    clients = _clients()
    closed = _run(_cfg(), clients, rounds=5)
    opened = _run(_cfg(scenario=ScenarioConfig(enabled=True)), clients,
                  rounds=5)
    assert _fingerprint(closed) == _fingerprint(opened)
    assert opened.ue_joins == opened.ue_departures == 0


def test_zero_rate_enabled_scenario_is_bitwise_on_mobile_hierarchy():
    clients = _clients()
    closed = _run(_cfg(mobility=_HIER), clients, rounds=5)
    opened = _run(_cfg(mobility=_HIER,
                       scenario=ScenarioConfig(enabled=True)), clients,
                  rounds=5)
    assert _fingerprint(closed) == _fingerprint(opened)
    assert closed.handovers == opened.handovers


def test_churn_run_is_seed_deterministic():
    clients = _clients()
    scen = ScenarioConfig(enabled=True, initial_active_frac=0.75,
                          arrival_rate=3.0, departure_rate=0.3,
                          min_active=4, drift_rate=0.5)
    a = _run(_cfg(mobility=_HIER, scenario=scen), _clients(), rounds=6)
    b = _run(_cfg(mobility=_HIER, scenario=scen), clients, rounds=6)
    assert _fingerprint(a) == _fingerprint(b)
    assert (a.ue_joins, a.ue_departures, a.label_drifts) \
        == (b.ue_joins, b.ue_departures, b.label_drifts)


# ---------------------------------------------------------------------------
# scenario registry (benchmarks/scenarios.py)
# ---------------------------------------------------------------------------

def test_registry_covers_required_scenarios_and_validates():
    from benchmarks.scenarios import scenario_registry
    reg = scenario_registry()
    assert {"static", "churn", "diurnal", "flash_crowd"} <= set(reg)
    assert not reg["static"].enabled
    for name, sc in reg.items():
        if not sc.enabled:
            continue
        # every catalogued config must construct a valid runtime
        scen = ScenarioRuntime(sc, 32, seed=0)
        assert scen.can_spawn() or sc.arrival_rate == 0.0
    assert reg["diurnal"].diurnal_amplitude > 0.0
    assert reg["flash_crowd"].flash_arrival_boost > 1.0
