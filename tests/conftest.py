import os
import sys

# tests must see the real host device count (1), NOT the dry-run's 512 —
# never set XLA_FLAGS here.  Subprocess tests set their own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def nprng():
    return np.random.default_rng(0)
