"""Metrics logger round-trips."""
import jax.numpy as jnp
import numpy as np

from repro.utils.metrics import MetricsLogger, read_metrics


def test_jsonl_roundtrip(tmp_path):
    with MetricsLogger(str(tmp_path), meta={"arch": "yi_6b"}) as log:
        log.log(step=0, loss=2.5, grad_norm=jnp.float32(1.25))
        log.log(step=1, loss=np.float64(2.25), acc=float("nan"),
                nested={"a": jnp.int32(3)})
    rows = read_metrics(str(tmp_path / "metrics.jsonl"))
    assert rows[0]["_meta"]["arch"] == "yi_6b"
    assert rows[1]["step"] == 0 and rows[1]["loss"] == 2.5
    assert abs(rows[1]["grad_norm"] - 1.25) < 1e-9
    assert rows[2]["acc"] is None                 # NaN → null
    assert rows[2]["nested"]["a"] == 3
    assert all("t" in r for r in rows[1:])


def test_append_mode(tmp_path):
    MetricsLogger(str(tmp_path)).log(step=0, x=1)
    MetricsLogger(str(tmp_path)).log(step=1, x=2)
    rows = read_metrics(str(tmp_path / "metrics.jsonl"))
    assert [r["x"] for r in rows] == [1, 2]
