"""Metrics logger round-trips + array coercion in ``_plain``."""
import jax.numpy as jnp
import numpy as np

from repro.utils.metrics import (ARRAY_ELEMS_CAP, MetricsLogger, _plain,
                                 read_metrics)


def test_jsonl_roundtrip(tmp_path):
    with MetricsLogger(str(tmp_path), meta={"arch": "yi_6b"}) as log:
        log.log(step=0, loss=2.5, grad_norm=jnp.float32(1.25))
        log.log(step=1, loss=np.float64(2.25), acc=float("nan"),
                nested={"a": jnp.int32(3)})
    rows = read_metrics(str(tmp_path / "metrics.jsonl"))
    assert rows[0]["_meta"]["arch"] == "yi_6b"
    assert rows[1]["step"] == 0 and rows[1]["loss"] == 2.5
    assert abs(rows[1]["grad_norm"] - 1.25) < 1e-9
    assert rows[2]["acc"] is None                 # NaN → null
    assert rows[2]["nested"]["a"] == 3
    assert all("t" in r for r in rows[1:])


def test_append_mode(tmp_path):
    MetricsLogger(str(tmp_path)).log(step=0, x=1)
    MetricsLogger(str(tmp_path)).log(step=1, x=2)
    rows = read_metrics(str(tmp_path / "metrics.jsonl"))
    assert [r["x"] for r in rows] == [1, 2]


def test_plain_small_arrays_become_lists():
    # non-0-d ndarrays used to fall through _plain un-coerced and crash
    # json.dumps at write time
    assert _plain(np.array([1, 2, 3])) == [1, 2, 3]
    assert _plain(jnp.arange(3, dtype=jnp.int32)) == [0, 1, 2]
    got = _plain(np.array([[1.5, float("nan")], [0.0, 2.0]]))
    assert got == [[1.5, None], [0.0, 2.0]]      # NaN → null, recursively
    assert _plain({"v": np.arange(2)}) == {"v": [0, 1]}


def test_plain_large_arrays_summarize_not_explode():
    big = np.zeros((4, ARRAY_ELEMS_CAP), dtype=np.float32)
    got = _plain(big)
    assert got == {"shape": [4, ARRAY_ELEMS_CAP], "dtype": "float32",
                   "size": 4 * ARRAY_ELEMS_CAP}
    # cap boundary: exactly ARRAY_ELEMS_CAP elements still inlines
    assert _plain(np.zeros(ARRAY_ELEMS_CAP)) == [0.0] * ARRAY_ELEMS_CAP


def test_logger_accepts_ndarray_values(tmp_path):
    with MetricsLogger(str(tmp_path)) as log:
        log.log(step=0, hist=np.array([3, 1, 0]),
                big=np.zeros(ARRAY_ELEMS_CAP + 1))
    rows = read_metrics(str(tmp_path / "metrics.jsonl"))
    assert rows[0]["hist"] == [3, 1, 0]
    assert rows[0]["big"]["size"] == ARRAY_ELEMS_CAP + 1
