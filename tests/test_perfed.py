"""Eq. (5)/(7) meta-gradient correctness against the autodiff oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ModelConfig
from repro.core import perfed
from repro.models import build_model
from repro.utils import tree_norm, tree_sub


def _quadratic_model():
    """f(w; x, y) = mean((x·w1 + b − y)^2) — analytically tractable."""
    class M:
        def init(self, rng):
            k1, k2 = jax.random.split(rng)
            return {"w": jax.random.normal(k1, (5, 3)),
                    "b": jax.random.normal(k2, (3,))}

        def loss(self, params, batch, rng=None):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean(jnp.square(pred - batch["y"])), {}
    return M()


@pytest.fixture
def setup():
    model = _quadratic_model()
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    kx, ky = jax.random.split(rng)
    batch = {"x": jax.random.normal(kx, (32, 5)),
             "y": jax.random.normal(ky, (32, 3))}
    return model, params, batch


def test_perfed_grad_matches_autodiff_oracle(setup):
    """With identical D_in = D_o = D_h, Eq. (7) must equal d/dw f(w−α∇f(w))."""
    model, params, batch = setup
    alpha = 0.05
    batches = {"inner": batch, "outer": batch, "hessian": batch}
    got = perfed.perfed_grad(model.loss, params, batches, alpha)
    want = perfed.perfed_grad_exact(model.loss, params, batch, alpha)
    err = float(tree_norm(tree_sub(got, want)) / tree_norm(want))
    assert err < 1e-5, err


def test_perfed_grad_on_neural_model():
    """Same identity through a real nonconvex model (2-layer DNN)."""
    cfg = ModelConfig(name="mnist_dnn", family="small", d_model=16,
                      vocab_size=10, dtype="float32")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    kx, ky = jax.random.split(jax.random.fold_in(rng, 1))
    batch = {"x": jax.random.normal(kx, (8, 28, 28)),
             "y": jax.random.randint(ky, (8,), 0, 10)}
    batches = {"inner": batch, "outer": batch, "hessian": batch}
    got = perfed.perfed_grad(model.loss, params, batches, 0.03)
    want = perfed.perfed_grad_exact(model.loss, params, batch, 0.03)
    err = float(tree_norm(tree_sub(got, want)) / tree_norm(want))
    assert err < 1e-4, err


def test_first_order_drops_hessian(setup):
    model, params, batch = setup
    batches = {"inner": batch, "outer": batch, "hessian": batch}
    fo = perfed.perfed_grad(model.loss, params, batches, 0.05,
                            first_order=True)
    w_ad = perfed.adapt(model.loss, params, batch, 0.05)
    want = jax.grad(lambda p: model.loss(p, batch)[0])(w_ad)
    err = float(tree_norm(tree_sub(fo, want)))
    assert err < 1e-6

    full = perfed.perfed_grad(model.loss, params, batches, 0.05)
    assert float(tree_norm(tree_sub(full, fo))) > 1e-4  # Hessian term matters


def test_adapt_reduces_loss(setup):
    model, params, batch = setup
    l0 = float(model.loss(params, batch)[0])
    adapted = perfed.adapt(model.loss, params, batch, 0.05)
    l1 = float(model.loss(adapted, batch)[0])
    assert l1 < l0


def test_perfed_loss_value(setup):
    model, params, batch = setup
    batches = {"inner": batch, "outer": batch}
    got = float(perfed.perfed_loss(model.loss, params, batches, 0.05))
    adapted = perfed.adapt(model.loss, params, batch, 0.05)
    want = float(model.loss(adapted, batch)[0])
    assert abs(got - want) < 1e-6


def test_alpha_zero_recovers_plain_gradient(setup):
    model, params, batch = setup
    batches = {"inner": batch, "outer": batch, "hessian": batch}
    got = perfed.perfed_grad(model.loss, params, batches, 0.0)
    want = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    assert float(tree_norm(tree_sub(got, want))) < 1e-6
