"""Checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import build_model


def test_roundtrip_nested(tmp_path, rng):
    tree = {"a": jax.random.normal(rng, (4, 4)),
            "b": {"c": jnp.arange(7), "d": jnp.float32(3.5).reshape(())}}
    f = save_checkpoint(str(tmp_path), tree, step=3)
    back = load_checkpoint(f, like=tree)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), tree, back)


def test_roundtrip_model_params(tmp_path, rng):
    cfg = get_config("yi_6b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    f = save_checkpoint(str(tmp_path), params, step=1)
    back = load_checkpoint(f, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_checkpoint(tmp_path, rng):
    tree = {"a": jnp.zeros(3)}
    save_checkpoint(str(tmp_path), tree, step=1)
    f2 = save_checkpoint(str(tmp_path), tree, step=12)
    assert latest_checkpoint(str(tmp_path)) == f2


def test_shape_mismatch_raises(tmp_path, rng):
    f = save_checkpoint(str(tmp_path), {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(f, like={"a": jnp.zeros((4,))})
