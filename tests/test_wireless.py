"""Wireless model — Eq. (9)–(12) properties."""
import numpy as np
import pytest

from repro.config import WirelessConfig
from repro.core.bandwidth import uplink_rate
from repro.wireless.channel import EdgeNetwork
from repro.wireless.timing import compute_time, model_bits, round_time, upload_time


@pytest.fixture(scope="module")
def net():
    return EdgeNetwork.drop(WirelessConfig(), 12, seed=0)


def test_drop_geometry(net):
    assert (net.distances <= 200.0).all() and (net.distances >= 5.0).all()
    assert net.cpu_freq.max() / net.cpu_freq.min() <= 4.0 * 1.001


def test_rate_decreases_with_distance(net):
    h = 40.0
    r_near = uplink_rate(5e4, net.channel(int(np.argmin(net.distances)), h))
    r_far = uplink_rate(5e4, net.channel(int(np.argmax(net.distances)), h))
    assert r_near > r_far


def test_rayleigh_fading_statistics(net):
    h = np.concatenate([net.sample_fading() for _ in range(200)])
    # Rayleigh(σ=40): mean = σ√(π/2) ≈ 50.13
    assert abs(h.mean() - 40 * np.sqrt(np.pi / 2)) < 2.0
    assert (h > 0).all()


def test_compute_time_eq11():
    assert compute_time(2e5, 48, 1e9) == pytest.approx(2e5 * 48 / 1e9)


def test_upload_time_decreasing_in_bandwidth(net):
    ch = net.channel(0, 40.0)
    assert upload_time(1e6, 2e5, ch) < upload_time(1e6, 1e5, ch)


def test_round_time_is_max():
    assert round_time(np.array([0.3, 1.2, 0.7])) == pytest.approx(1.2)


def test_model_bits():
    import jax.numpy as jnp
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert model_bits(params) == 105 * 32


def test_uniform_distance_mode():
    net_u = EdgeNetwork.drop(WirelessConfig(), 6, seed=1,
                             uniform_distance=True)
    assert np.allclose(net_u.distances, net_u.distances[0])
