"""Wireless model — Eq. (9)–(12) properties."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # clean container
    from repro.utils.hypofallback import given, settings, strategies as st

from repro.config import WirelessConfig
from repro.core.bandwidth import UEChannel, uplink_rate
from repro.wireless.channel import EdgeNetwork
from repro.wireless.timing import compute_time, model_bits, round_time, upload_time


@pytest.fixture(scope="module")
def net():
    return EdgeNetwork.drop(WirelessConfig(), 12, seed=0)


def test_drop_geometry(net):
    assert (net.distances <= 200.0).all() and (net.distances >= 5.0).all()
    assert net.cpu_freq.max() / net.cpu_freq.min() <= 4.0 * 1.001


def test_rate_decreases_with_distance(net):
    h = 40.0
    r_near = uplink_rate(5e4, net.channel(int(np.argmin(net.distances)), h))
    r_far = uplink_rate(5e4, net.channel(int(np.argmax(net.distances)), h))
    assert r_near > r_far


def test_rayleigh_fading_statistics(net):
    h = np.concatenate([net.sample_fading() for _ in range(200)])
    # Rayleigh(σ=40): mean = σ√(π/2) ≈ 50.13
    assert abs(h.mean() - 40 * np.sqrt(np.pi / 2)) < 2.0
    assert (h > 0).all()


def test_compute_time_eq11():
    assert compute_time(2e5, 48, 1e9) == pytest.approx(2e5 * 48 / 1e9)


def test_upload_time_decreasing_in_bandwidth(net):
    ch = net.channel(0, 40.0)
    assert upload_time(1e6, 2e5, ch) < upload_time(1e6, 1e5, ch)


def test_round_time_is_max():
    assert round_time(np.array([0.3, 1.2, 0.7])) == pytest.approx(1.2)


def test_round_time_empty_schedule_is_zero():
    """An empty scheduled set (e.g. an idle hierarchical cell) costs no
    time instead of raising a bare ValueError from np.max([])."""
    assert round_time(np.array([])) == 0.0
    assert round_time([]) == 0.0


def test_model_bits():
    import jax.numpy as jnp
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert model_bits(params) == 105 * 32


def test_model_bits_16_bit_payloads():
    import jax.numpy as jnp
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert model_bits(params, bits_per_param=16) == 105 * 16
    assert model_bits(params, 16) == model_bits(params) / 2
    with pytest.raises(ValueError):
        model_bits(params, bits_per_param=0)


def test_bits_per_param_halves_simulated_upload_time():
    """fp16 payloads plumb end-to-end: the simulator's z_bits derivation
    honours WirelessConfig.bits_per_param, halving Eq.-10 upload time."""
    import jax.numpy as jnp
    params = {"w": jnp.zeros((64, 64))}
    cfg32 = WirelessConfig()
    cfg16 = dataclasses.replace(cfg32, bits_per_param=16)
    z32 = cfg32.grad_bits or model_bits(params, cfg32.bits_per_param)
    z16 = cfg16.grad_bits or model_bits(params, cfg16.bits_per_param)
    assert z16 == z32 / 2
    ch = UEChannel(p=0.01, h=40.0, dist=100.0, kappa=3.8, n0=3.98e-21)
    assert upload_time(z16, 5e4, ch) == pytest.approx(
        upload_time(z32, 5e4, ch) / 2)


def test_uniform_distance_mode():
    net_u = EdgeNetwork.drop(WirelessConfig(), 6, seed=1,
                             uniform_distance=True)
    assert np.allclose(net_u.distances, net_u.distances[0])


# ---------------------------------------------------------------------------
# channel physics — property tests
# ---------------------------------------------------------------------------

def _channel(dist: float, h: float = 40.0) -> UEChannel:
    from repro.wireless.channel import make_channel
    return make_channel(WirelessConfig(), dist, h)


@settings(max_examples=30, deadline=None)
@given(b_lo=st.floats(min_value=1e3, max_value=5e5),
       scale=st.floats(min_value=1.01, max_value=10.0),
       dist=st.floats(min_value=5.0, max_value=200.0))
def test_uplink_rate_monotone_in_bandwidth(b_lo, scale, dist):
    """Eq. 9: r(b) = b·ln(1 + q/b) is strictly increasing in b (the fact
    Theorem 2's equal-finish argument rests on)."""
    ch = _channel(dist)
    assert uplink_rate(b_lo * scale, ch) > uplink_rate(b_lo, ch)


@settings(max_examples=30, deadline=None)
@given(d_lo=st.floats(min_value=5.0, max_value=150.0),
       scale=st.floats(min_value=1.01, max_value=5.0),
       b=st.floats(min_value=1e3, max_value=1e6))
def test_uplink_rate_decreasing_in_distance(d_lo, scale, b):
    """Path loss d^{−κ}: farther UEs upload strictly slower at any b."""
    assert uplink_rate(b, _channel(d_lo * scale)) < \
        uplink_rate(b, _channel(d_lo))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_sample_fading_deterministic_per_seed(seed):
    a = EdgeNetwork.drop(WirelessConfig(), 9, seed=seed)
    b = EdgeNetwork.drop(WirelessConfig(), 9, seed=seed)
    for _ in range(3):                     # the whole stream, not just draw 1
        np.testing.assert_array_equal(a.sample_fading(), b.sample_fading())
    c = EdgeNetwork.drop(WirelessConfig(), 9, seed=seed + 1)
    assert not np.array_equal(a.sample_fading(), c.sample_fading())
