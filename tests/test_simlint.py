"""simlint rule fixtures + clean-tree gate.

Each fixture seeds one violation and asserts the exact rule code AND
line; negative twins assert the idiomatic form stays clean.  The final
test runs the real checker over the real tree with the committed
baseline and requires zero unsuppressed findings — the same gate CI
applies.
"""
from pathlib import Path

import pytest

from repro.analysis import Baseline, lint_text
from repro.analysis.core import find_repo_root, run_paths

SRC = "src/repro/fl/somemod.py"          # a library path (rules scoped on)
HOT = "src/repro/fl/driver.py"           # a hot-path module for SIM2xx


def codes_at(findings, code):
    return [f.line for f in findings if f.code == code
            and f.status == "active"]


# ----------------------------------------------------------------------
# SIM101 — key reuse
# ----------------------------------------------------------------------
def test_sim101_reused_key_flagged():
    snippet = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.uniform(key, (3,))\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == [4]


def test_sim101_split_consumes_key():
    snippet = (
        "import jax\n"
        "def f(key):\n"
        "    k1, k2 = jax.random.split(key)\n"
        "    return jax.random.normal(key, (3,))\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == [4]


def test_sim101_rebinding_is_clean():
    snippet = (
        "import jax\n"
        "def f(key):\n"
        "    key, sub = jax.random.split(key)\n"
        "    a = jax.random.normal(sub, (3,))\n"
        "    key, sub = jax.random.split(key)\n"
        "    b = jax.random.normal(sub, (3,))\n"
        "    return a + b\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == []


def test_sim101_fold_in_derivation_is_clean():
    snippet = (
        "import jax\n"
        "def f(key, n):\n"
        "    return [jax.random.normal(jax.random.fold_in(key, i), (3,))\n"
        "            for i in range(n)]\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == []


def test_sim101_branches_do_not_cross_taint():
    # a draw in the if-arm must not mark the key consumed for the else-arm
    snippet = (
        "import jax\n"
        "def f(key, p):\n"
        "    if p:\n"
        "        return jax.random.normal(key, (3,))\n"
        "    else:\n"
        "        return jax.random.uniform(key, (3,))\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == []


def test_sim101_loop_reuse_flagged():
    snippet = (
        "import jax\n"
        "def f(key, n):\n"
        "    out = []\n"
        "    for _ in range(n):\n"
        "        out.append(jax.random.normal(key, (3,)))\n"
        "    return out\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == [5]


def test_sim101_sees_through_import_alias():
    snippet = (
        "from jax import random as jr\n"
        "def f(key):\n"
        "    a = jr.normal(key, (3,))\n"
        "    b = jr.normal(key, (3,))\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM101") == [4]


# ----------------------------------------------------------------------
# SIM102 — literal seeds
# ----------------------------------------------------------------------
def test_sim102_literal_seed_flagged_in_library():
    snippet = (
        "import jax\n"
        "def init():\n"
        "    return jax.random.PRNGKey(0)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM102") == [3]


def test_sim102_config_seed_is_clean():
    snippet = (
        "import jax\n"
        "def init(seed):\n"
        "    return jax.random.PRNGKey(seed)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM102") == []


def test_sim102_tests_are_exempt():
    snippet = (
        "import jax\n"
        "key = jax.random.PRNGKey(0)\n"
    )
    assert codes_at(lint_text(snippet, "tests/test_x.py"),
                    "SIM102") == []


# ----------------------------------------------------------------------
# SIM103 — host RNG in library code
# ----------------------------------------------------------------------
def test_sim103_np_random_flagged():
    snippet = (
        "import numpy as np\n"
        "def f(seed):\n"
        "    rng = np.random.default_rng(seed)\n"
        "    return rng\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM103") == [3]


def test_sim103_stdlib_random_import_flagged():
    snippet = "import random\n"
    assert codes_at(lint_text(snippet, SRC), "SIM103") == [1]


def test_sim103_jax_random_alias_not_confused_with_stdlib():
    snippet = (
        "from jax import random\n"
        "def f(key):\n"
        "    return random.normal(key, (3,))\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM103") == []


def test_sim103_outside_src_repro_is_exempt():
    snippet = (
        "import numpy as np\n"
        "rng = np.random.default_rng(0)\n"
    )
    assert codes_at(lint_text(snippet, "benchmarks/b.py"),
                    "SIM103") == []


# ----------------------------------------------------------------------
# SIM104 — draw schedule branching on Python data (the PR-5 shape)
# ----------------------------------------------------------------------
def test_sim104_conditional_draw_flagged():
    snippet = (
        "import numpy as np\n"
        "def step(rng, moving):\n"
        "    if moving:\n"
        "        return rng.uniform(size=4)\n"
        "    return None\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM104") == [4]


def test_sim104_unconditional_draw_is_clean():
    snippet = (
        "import numpy as np\n"
        "def step(rng):\n"
        "    return rng.uniform(size=4)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM104") == []


def test_sim104_jax_draw_in_while_flagged():
    snippet = (
        "import jax\n"
        "def f(key, xs):\n"
        "    while xs:\n"
        "        key = jax.random.fold_in(key, 1)\n"
        "        x = jax.random.normal(key, (2,))\n"
        "        xs = xs[1:]\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM104") == [5]


# ----------------------------------------------------------------------
# SIM2xx — host/device boundary (hot-path scope)
# ----------------------------------------------------------------------
def test_sim201_item_flagged_in_hot_path():
    snippet = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return x.sum().item()\n"
    )
    assert codes_at(lint_text(snippet, HOT), "SIM201") == [3]


def test_sim201_non_hot_path_exempt():
    snippet = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return x.sum().item()\n"
    )
    assert codes_at(lint_text(snippet, "src/repro/utils/m.py"),
                    "SIM201") == []


def test_sim202_asarray_flagged_and_suppressible():
    flagged = (
        "import jax\n"
        "import numpy as np\n"
        "def f(x):\n"
        "    return np.asarray(x)\n"
    )
    assert codes_at(lint_text(flagged, HOT), "SIM202") == [4]
    suppressed = (
        "import jax\n"
        "import numpy as np\n"
        "def f(x):\n"
        "    # simlint: disable-next=SIM202 -- x is a host list\n"
        "    return np.asarray(x)\n"
    )
    found = lint_text(suppressed, HOT)
    assert codes_at(found, "SIM202") == []
    assert [f.status for f in found if f.code == "SIM202"] == \
        ["suppressed"]


def test_sim203_scalar_coercion_flagged():
    snippet = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return float(jnp.max(x))\n"
    )
    assert codes_at(lint_text(snippet, HOT), "SIM203") == [3]


def test_sim203_shape_metadata_is_clean():
    snippet = (
        "import jax\n"
        "def f(tree):\n"
        "    return int(jax.tree_util.tree_leaves(tree)[0].shape[0])\n"
    )
    assert codes_at(lint_text(snippet, HOT), "SIM203") == []


# ----------------------------------------------------------------------
# SIM3xx — jit purity
# ----------------------------------------------------------------------
def test_sim301_print_in_jit_decorated_fn():
    snippet = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    print('tracing', x)\n"
        "    return x + 1\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM301") == [4]


def test_sim301_reaches_through_call_graph():
    snippet = (
        "import jax\n"
        "def helper(x):\n"
        "    print(x)\n"
        "    return x * 2\n"
        "def outer(x):\n"
        "    return helper(x)\n"
        "g = jax.jit(outer)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM301") == [3]


def test_sim301_untraced_fn_may_print():
    snippet = (
        "def report(x):\n"
        "    print(x)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM301") == []


def test_sim302_time_in_scanned_fn():
    snippet = (
        "import time\n"
        "import jax\n"
        "from jax import lax\n"
        "def body(carry, x):\n"
        "    t = time.perf_counter()\n"
        "    return carry + x, t\n"
        "def run(xs):\n"
        "    return lax.scan(body, 0.0, xs)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM302") == [5]


def test_sim303_tracer_span_in_jit():
    snippet = (
        "import jax\n"
        "from repro import obs\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    obs.CURRENT.add('inner')\n"
        "    return x + 1\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM303") == [5]


def test_sim304_nonlocal_mutation_in_jit():
    snippet = (
        "import jax\n"
        "acc = []\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    acc.append(x)\n"
        "    return x + 1\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM304") == [5]


def test_sim304_local_container_is_clean():
    snippet = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    parts = []\n"
        "    parts.append(x)\n"
        "    return parts[0]\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM304") == []


def test_sim304_pallas_ref_store_is_clean():
    snippet = (
        "import jax\n"
        "from jax.experimental import pallas as pl\n"
        "def kernel(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...] * 2\n"
        "def run(x):\n"
        "    return pl.pallas_call(kernel, out_shape=x)(x)\n"
    )
    assert codes_at(lint_text(snippet, SRC), "SIM304") == []


# ----------------------------------------------------------------------
# SIM4xx — observability read-only
# ----------------------------------------------------------------------
def test_sim401_obs_importing_simulator_flagged():
    snippet = "from repro.fl import driver\n"
    assert codes_at(lint_text(snippet, "src/repro/obs/bad.py"),
                    "SIM401") == [1]


def test_sim401_obs_allowlist_is_clean():
    snippet = (
        "from repro.obs import trace\n"
        "from repro.utils import metrics\n"
    )
    assert codes_at(lint_text(snippet, "src/repro/obs/ok.py"),
                    "SIM401") == []


def test_sim402_obs_calling_mutator_flagged():
    snippet = (
        "def peek(net):\n"
        "    net.advance_to(4.0)\n"
        "    return net.positions\n"
    )
    assert codes_at(lint_text(snippet, "src/repro/obs/bad.py"),
                    "SIM402") == [2]


# ----------------------------------------------------------------------
# suppression / baseline machinery
# ----------------------------------------------------------------------
def test_suppression_same_line_and_file_wide():
    same_line = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.normal(key, (3,))"
        "  # simlint: disable=SIM101 -- twin draw wanted\n"
    )
    assert codes_at(lint_text(same_line, SRC), "SIM101") == []
    file_wide = (
        "# simlint: disable-file=SIM101\n"
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.normal(key, (3,))\n"
    )
    assert codes_at(lint_text(file_wide, SRC), "SIM101") == []


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text('{"entries": [{"file": "a.py", "code": "SIM103",'
                 ' "match": "x = 1", "why": ""}]}')
    with pytest.raises(ValueError):
        Baseline.load(p)


def test_repo_baseline_entries_all_justified():
    root = find_repo_root(Path(__file__))
    baseline = Baseline.load(root / "simlint-baseline.json")
    assert baseline.entries, "baseline exists but is empty"
    for e in baseline.entries:
        assert len(e.why.strip()) > 10, (e.file, e.code)


# ----------------------------------------------------------------------
# the gate: the committed tree has zero unsuppressed findings
# ----------------------------------------------------------------------
def test_clean_tree_zero_active_findings():
    root = find_repo_root(Path(__file__))
    baseline = Baseline.load(root / "simlint-baseline.json")
    report = run_paths(
        [root / "src", root / "benchmarks", root / "examples",
         root / "scripts", root / "tests"],
        repo_root=root, baseline=baseline)
    assert report.errors == []
    assert [f.render() for f in report.active] == []
    assert [(e.file, e.code) for e in report.stale_baseline] == []
    # ≥ 4 rule families exercised on the real tree (suppressed/baselined
    # findings still prove the family fires)
    families = {f.code[:4] for f in report.findings}
    assert {"SIM1", "SIM2"} <= families
