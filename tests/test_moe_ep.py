"""Expert-parallel (shard_map) MoE ≡ gather MoE — subprocess with 8 devices."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.config import ModelConfig, MoEConfig
from repro.launch.mesh import make_mesh
from repro.models import layers as L

n_experts = int(sys.argv[1])   # 8 → e_loc=2 path; 2 → rep=2 virtual-expert path
mesh = make_mesh((2, 4), ("data", "model"))
cfg = ModelConfig(name="moe-ep-test", family="moe", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                  dtype="float32",
                  moe=MoEConfig(num_experts=n_experts, experts_per_token=2,
                                expert_d_ff=64, capacity_factor=8.0))
rng = jax.random.PRNGKey(0)
p = L.moe_init(rng, cfg)
x = jax.random.normal(jax.random.fold_in(rng, 1), (4, 16, 32))

rules = sharding.AxisRules()
if n_experts % mesh.shape["model"]:
    rules = rules.with_overrides(experts=())   # same fix-up as arch_rules()

with sharding.use_mesh(mesh, rules):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.device_put(p, sharding.param_shardings(p, mesh, rules))
    out_g, aux_g = jax.jit(lambda p_, x_: L.moe_apply_gather(p_, x_, cfg))(ps, xs)
    out_e, aux_e = jax.jit(lambda p_, x_: L.moe_apply_ep(p_, x_, cfg))(ps, xs)

err = float(jnp.max(jnp.abs(out_g - out_e)))
aux_err = abs(float(aux_g) - float(aux_e))
print(json.dumps({"err": err, "aux_err": aux_err}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("n_experts", [8, 2])   # e_loc=2 path / rep=2 path
def test_moe_ep_matches_gather_on_mesh(n_experts):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, str(n_experts)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-4, rec
    assert rec["aux_err"] < 1e-5, rec
