"""Footnote-1 extension: transmit-power control (inverse of Eq. 9 in p)."""
import numpy as np

from repro.core.bandwidth import (UEChannel, min_power_equal_finish,
                                  power_for_time, uplink_rate)

N0 = 10 ** (-174.0 / 10.0) / 1000.0


def _ch(p=0.01, h=40.0, d=100.0):
    return UEChannel(p=p, h=h, dist=d, kappa=3.8, n0=N0)


def test_power_for_time_inverts_rate():
    ch = _ch()
    z, tcmp, t, b = 4e5, 0.05, 0.4, 2e5
    p = power_for_time(z, t, tcmp, b, ch)
    # at that power, upload time must equal t − tcmp
    ch2 = UEChannel(p=p, h=ch.h, dist=ch.dist, kappa=ch.kappa, n0=ch.n0)
    t_up = z * np.log(2) / uplink_rate(b, ch2)
    assert abs(t_up - (t - tcmp)) / (t - tcmp) < 1e-9


def test_power_monotone_in_deadline():
    ch = _ch()
    p_tight = power_for_time(4e5, 0.2, 0.05, 2e5, ch)
    p_loose = power_for_time(4e5, 0.8, 0.05, 2e5, ch)
    assert p_tight > p_loose > 0


def test_power_cap_infeasible():
    ch = _ch()
    assert power_for_time(1e7, 0.06, 0.05, 1e4, ch, p_max=0.01) == float("inf")
    assert power_for_time(4e5, 0.04, 0.05, 2e5, ch) == float("inf")


def test_min_power_equal_finish_vector():
    chans = [_ch(d=50), _ch(d=120), _ch(d=190)]
    z = [4e5] * 3
    tcmp = [0.05, 0.1, 0.15]
    b = [3e5, 3e5, 4e5]
    p = min_power_equal_finish(z, tcmp, b, chans, t_star=0.5)
    assert (p > 0).all() and np.isfinite(p).all()
    # farther UE with same bandwidth needs more power
    p2 = min_power_equal_finish([4e5, 4e5], [0.05, 0.05], [3e5, 3e5],
                                [_ch(d=50), _ch(d=190)], t_star=0.5)
    assert p2[1] > p2[0]
