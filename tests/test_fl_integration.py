"""End-to-end FL simulation behaviour — the paper's qualitative claims."""
import numpy as np
import pytest

from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=10, participants_per_round=3, staleness_bound=3,
                    rounds=25, alpha=0.03, beta=0.07, inner_batch=16,
                    outer_batch=16, hessian_batch=16))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=2500, seed=3)
    clients = partition_noniid(data, 10, n_labels=4, seed=3)
    return cfg, model, clients


def test_perfeds2_converges(setup):
    cfg, model, clients = setup
    res = run_simulation(cfg, model, clients, algorithm="perfed", mode="semi",
                         max_rounds=25, eval_every=25, seed=1)
    assert res.losses[-1] < 0.6 * res.losses[0]
    assert (res.pi.sum(1) == 3).all()                   # Eq. (14) realised


def test_semi_faster_than_sync_wallclock(setup):
    """Straggler mitigation: wall-clock to finish K rounds of A updates must
    be smaller semi-sync than fully-sync for the same total gradient count.

    Uses S ≥ n/A (the paper's own Fig.-10 setting: "when S ≥ 5, all the
    scheduled UEs would arrive within S rounds") so no in-flight work is
    abandoned — with a too-small S the forced refresh wastes computation,
    which is exactly the C1.5 phenomenon, not a straggler-mitigation test."""
    import dataclasses
    cfg, model, clients = setup
    # heterogeneous uplinks (distance-drop) = the paper's straggler regime;
    # equal-distance drops make semi ≈ sync by construction
    cfg = dataclasses.replace(cfg, fl=dataclasses.replace(
        cfg.fl, staleness_bound=8, eta_mode="distance"))
    k = 12
    res_semi = run_simulation(cfg, model, clients, algorithm="perfed",
                              mode="semi", max_rounds=k, eval_every=100,
                              seed=2)
    # sync waits for all n=10 per round → same #grads after k·A/n rounds
    k_sync = max(1, k * 3 // 10)
    res_sync = run_simulation(cfg, model, clients, algorithm="perfed",
                              mode="sync", max_rounds=k_sync, eval_every=100,
                              seed=2)
    grads_semi = res_semi.pi.sum()
    grads_sync = res_sync.pi.sum()
    t_per_grad_semi = res_semi.total_time / grads_semi
    t_per_grad_sync = res_sync.total_time / grads_sync
    assert t_per_grad_semi < t_per_grad_sync * 1.05


def test_async_is_mode_a_equals_one(setup):
    cfg, model, clients = setup
    res = run_simulation(cfg, model, clients, algorithm="perfed",
                         mode="async", max_rounds=10, eval_every=100, seed=1)
    assert (res.pi.sum(1) == 1).all()


def test_personalization_gain(setup):
    """Per-FedAvg's meta-initialisation adapts better than FedAvg's global
    model when client label distributions CONFLICT (per-client label
    permutations — no single model fits everyone): compare the same PFL
    metric (post-adaptation loss) for both."""
    from repro.data.partition import ClientDataset
    from repro.data.synthetic import conflicting_label_clients
    import numpy as _np
    cfg, model, _ = setup
    shards = conflicting_label_clients(10, n_per_client=250, n_swap=6, seed=9)
    hetero = []
    for ci, d in enumerate(shards):
        n_test = len(d["y"]) // 5
        hetero.append(ClientDataset(
            data={k: v[n_test:] for k, v in d.items()},
            test={k: v[:n_test] for k, v in d.items()},
            labels_held=_np.unique(d["y"]),
            rng=_np.random.default_rng(100 + ci)))
    res_pf = run_simulation(cfg, model, hetero, algorithm="perfed",
                            mode="semi", max_rounds=30, eval_every=30, seed=4)
    res_fa = run_simulation(cfg, model, hetero, algorithm="fedavg",
                            mode="semi", max_rounds=30, eval_every=30, seed=4)
    assert res_pf.losses[-1] < res_fa.losses[-1] * 1.05


def test_fedprox_runs(setup):
    cfg, model, clients = setup
    res = run_simulation(cfg, model, clients, algorithm="fedprox",
                         mode="semi", max_rounds=8, eval_every=100, seed=1)
    assert np.isfinite(res.losses[-1])


def test_optimal_bandwidth_not_slower_than_equal(setup):
    cfg, model, clients = setup
    r_opt = run_simulation(cfg, model, clients, algorithm="perfed",
                           mode="semi", bandwidth_policy="optimal",
                           max_rounds=10, eval_every=100, seed=5)
    r_eq = run_simulation(cfg, model, clients, algorithm="perfed",
                          mode="semi", bandwidth_policy="equal",
                          max_rounds=10, eval_every=100, seed=5)
    assert r_opt.total_time <= r_eq.total_time * 1.10
