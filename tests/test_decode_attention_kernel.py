"""Decode-attention Pallas kernel vs oracle: shape/window/ring sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_bhsd

CASES = [
    # (B, Hq, Hkv, S, D, block)
    (2, 4, 2, 128, 32, 64),
    (1, 8, 1, 200, 64, 64),     # MQA, ragged S
    (3, 2, 2, 64, 16, 32),
]


def _setup(rng, b, hq, hkv, s, d, fill_frac=1.0):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    n_valid = max(1, int(s * fill_frac))
    pos = jnp.where(jnp.arange(s)[None] < n_valid,
                    jnp.arange(s)[None], -1) * jnp.ones((b, 1), jnp.int32)
    q_pos = jnp.full((b,), n_valid - 1, jnp.int32)
    return q, k, v, pos.astype(jnp.int32), q_pos


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", CASES)
@pytest.mark.parametrize("window", [0, 48])
def test_decode_attention_matches_ref(b, hq, hkv, s, d, blk, window, rng):
    q, k, v, pos, q_pos = _setup(rng, b, hq, hkv, s, d)
    got = decode_attention_bhsd(q, k, v, pos, q_pos, window=window,
                                block_s=blk)
    want = ref.decode_attention_ref(q, k, v, pos, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_partially_filled_ring_cache(rng):
    """Empty slots (pos = −1) must be ignored by the online softmax."""
    q, k, v, pos, q_pos = _setup(rng, 2, 4, 2, 128, 32, fill_frac=0.3)
    got = decode_attention_bhsd(q, k, v, pos, q_pos, block_s=64)
    want = ref.decode_attention_ref(q, k, v, pos, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_matches_model_sdpa_decode_path(rng):
    """Kernel ≡ the model's XLA decode attention on the same cache layout."""
    from repro.models import layers as L
    b, hq, hkv, s, d = 2, 4, 2, 96, 32
    q, k, v, pos, q_pos = _setup(rng, b, hq, hkv, s, d, fill_frac=0.8)
    # model layout: q [B,1,Hq,D], cache k/v [B,S,Hkv,D]
    out_model = L.sdpa(q[:, None].swapaxes(1, 2).reshape(b, 1, hq, d),
                       k.swapaxes(1, 2), v.swapaxes(1, 2),
                       q_pos=q_pos[:, None], k_pos=pos, causal=True, window=0)
    got = decode_attention_bhsd(q, k, v, pos, q_pos)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(out_model[:, 0]), atol=2e-5)
