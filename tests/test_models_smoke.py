"""Per-assigned-architecture smoke tests: REDUCED same-family variants run one
forward + one PerFed train step on CPU; output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ExperimentConfig, FLConfig
from repro.configs import ARCH_IDS, get_config
from repro.core import semi_sync
from repro.models import build_model
from repro.optim import make_optimizer

ASSIGNED = [a for a in ARCH_IDS if a not in ("mnist_dnn", "lenet5",
                                             "char_lstm")]


def _batch(cfg, rng, b=2, sl=64):
    if cfg.family == "audio":
        shape = (b, sl, cfg.num_audio_codebooks)
    else:
        shape = (b, sl)
    toks = jax.random.randint(rng, shape, 0, cfg.vocab_size)
    return {"tokens": toks, "targets": toks}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    logits = model.predict(params, batch)
    b, sl = batch["tokens"].shape[0], batch["tokens"].shape[1]
    if cfg.family == "audio":
        assert logits.shape == (b, sl, cfg.num_audio_codebooks,
                                cfg.vocab_size)
    else:
        assert logits.shape == (b, sl, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_perfed_train_step(arch, rng):
    """One paper-faithful PerFed step (inner adapt + HVP) must run and
    produce finite loss + a parameter change."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    exp = ExperimentConfig(model=cfg, fl=FLConfig(alpha=0.01, beta=0.05))
    opt = make_optimizer("sgd")
    step = semi_sync.make_train_step(model, exp, opt, perfed_step=True)
    state = semi_sync.init_train_state(model, rng, opt)
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    batches = {"inner": _batch(cfg, r1), "outer": _batch(cfg, r2),
               "hessian": _batch(cfg, r3)}
    new_state, metrics = jax.jit(step)(state, batches, r4)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params must move
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["mnist_dnn", "lenet5", "char_lstm"])
def test_paper_models(arch, rng):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(rng)
    k1, k2 = jax.random.split(jax.random.fold_in(rng, 1))
    if arch == "char_lstm":
        batch = {"tokens": jax.random.randint(k1, (2, 16), 0, cfg.vocab_size),
                 "targets": jax.random.randint(k2, (2, 16), 0, cfg.vocab_size)}
    else:
        hw = 28 if arch == "mnist_dnn" else 32
        shape = (2, hw, hw) if arch == "mnist_dnn" else (2, hw, hw, 3)
        batch = {"x": jax.random.normal(k1, shape),
                 "y": jax.random.randint(k2, (2,), 0, cfg.vocab_size)}
    loss, aux = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
