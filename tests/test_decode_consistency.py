"""Prefill + stepwise decode must reproduce the full-sequence forward pass
(teacher forcing) for every family — the serving-path correctness invariant."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model

CASES = ["yi_6b", "starcoder2_15b", "mixtral_8x22b", "deepseek_v2_236b",
         "mamba2_370m", "recurrentgemma_2b", "musicgen_large",
         "llama32_vision_11b", "nemotron4_15b", "deepseek_67b"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_then_decode_matches_full_forward(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    b, l_pre, n_dec = 2, 24, 4
    total = l_pre + n_dec
    if cfg.family == "audio":
        toks = jax.random.randint(rng, (b, total, cfg.num_audio_codebooks),
                                  0, cfg.vocab_size)
    else:
        toks = jax.random.randint(rng, (b, total), 0, cfg.vocab_size)

    # full forward logits (teacher-forced)
    if cfg.family == "vlm":
        img = model.stub_image_embeds(b)
        full_logits, _, _ = model.forward(params, toks, image_embeds=img)
    else:
        full_logits, _, _ = model.forward(params, toks)

    # prefill on the first l_pre tokens, then decode the rest one by one
    logits_last, cache = model.prefill(params, toks[:, :l_pre], 64)
    got = [logits_last]
    for i in range(n_dec - 1):
        nxt = toks[:, l_pre + i:l_pre + i + 1]
        logits, cache = model.decode_step(params, cache, nxt,
                                          jnp.int32(l_pre + i))
        got.append(logits)
    got = jnp.concatenate(got, axis=1)                      # [B, n_dec, ...]
    want = full_logits[:, l_pre - 1:l_pre - 1 + n_dec]

    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-6
    assert err / scale < 5e-2, f"{arch}: rel err {err/scale:.4f}"


def test_ring_buffer_matches_windowed_attention(rng):
    """Sliding-window decode with a ring cache == full cache + window mask."""
    cfg = get_config("yi_6b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    b, l_pre, w = 1, 40, 16
    toks = jax.random.randint(rng, (b, l_pre + 3), 0, cfg.vocab_size)

    # windowed forward over the full sequence (oracle)
    full_logits, _, _ = model.forward(params, toks, window=w)

    # ring cache of exactly w slots
    logits_last, cache = model.prefill(params, toks[:, :l_pre], w, window=w)
    assert cache["k"].shape[2] == w
    got = [logits_last]
    for i in range(2):
        logits, cache = model.decode_step(params, cache,
                                          toks[:, l_pre + i:l_pre + i + 1],
                                          jnp.int32(l_pre + i), window=w)
        got.append(logits)
    got = jnp.concatenate(got, axis=1)
    want = full_logits[:, l_pre - 1:l_pre + 2]
    err = float(jnp.max(jnp.abs(got - want)))
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    assert err / scale < 5e-2, err / scale
