"""The wireless side of the paper end-to-end: drop UEs in a cell, derive η
from their channels, build the Alg.-2 schedule, and compare bandwidth
allocation policies (Theorem 2/4 vs naive equal split).

    PYTHONPATH=src python examples/wireless_scheduling.py
"""
import numpy as np

from repro.config import FLConfig, WirelessConfig
from repro.core.bandwidth import (equal_finish_allocation, uplink_rate,
                                  weighted_equal_rate_allocation)
from repro.core.convergence import (SmoothnessParams, gamma_F2, sigma_F2,
                                    smoothness_F)
from repro.core.scheduler import (estimate_A_K, get_policy, greedy_schedule,
                                  schedule_period)
from repro.wireless.channel import EdgeNetwork

LN2 = np.log(2)

# --- 1) drop 8 UEs in a 200 m cell ------------------------------------------
wcfg = WirelessConfig()
net = EdgeNetwork.drop(wcfg, 8, seed=1)
print("distances [m]:", net.distances.round(1))
print("CPU freq [GHz]:", (net.cpu_freq / 1e9).round(2))

# --- 2) rate-derived relative participation frequencies η (SchedulingPolicy) -
policy = get_policy("rates")
eta = policy.frequencies(8, net)
print("\nη (rate-derived):", eta.round(3))

# --- 3) theory → A*, K* (Eq. 42/43) ------------------------------------------
p = SmoothnessParams()
fl = FLConfig(alpha=0.03, beta=0.05, staleness_bound=3)
l_f = smoothness_F(p, fl.alpha)
a_star, k_star = estimate_A_K(fl, eta=eta, epsilon=0.8, L_F=l_f,
                              sigma_F2=sigma_F2(p, fl.alpha, 16, 16, 16),
                              gamma_F2=gamma_F2(p, fl.alpha))
print(f"A* = {a_star}, K* = {k_star}")

# --- 4) Algorithm 2 greedy schedule (the policy's planner) -------------------
pi = policy.plan(eta, a_star, 12)
print(f"\nΠ (first 12 rounds, period={schedule_period(pi)}):")
print(pi)

# --- 5) bandwidth allocation for a round's scheduled set ---------------------
# (use A=3 here so the allocation demo has a multi-UE round even if A*=1)
pi3 = greedy_schedule(eta, max(a_star, 3), 12)
sched = np.where(pi3[0] == 1)[0]
h = net.sample_fading()
chans = [net.channel(int(i), h[int(i)]) for i in sched]
z = [4e5] * len(sched)
tcmp = [wcfg.cpu_cycles_per_sample * 48 / net.cpu_freq[int(i)]
        for i in sched]

b_opt, t_star, converged = equal_finish_allocation(
    z, tcmp, chans, wcfg.total_bandwidth_hz)
assert converged, "Theorem-2 bisection did not converge"
b_eq = np.full(len(sched), wcfg.total_bandwidth_hz / len(sched))

def round_time(b):
    return max(tcmp[i] + z[i] * LN2 / uplink_rate(b[i], chans[i])
               for i in range(len(sched)))

print(f"\nscheduled UEs: {sched}")
print(f"Theorem-2 equal-finish allocation [kHz]: {(b_opt/1e3).round(1)}")
print(f"  round time: {round_time(b_opt)*1e3:.1f} ms (all UEs finish together)")
print(f"naive equal split: {round_time(b_eq)*1e3:.1f} ms")
print(f"→ straggler saving: {round_time(b_eq)/round_time(b_opt):.2f}×")

b_wer = weighted_equal_rate_allocation(eta, net.channels(h),
                                       wcfg.total_bandwidth_hz)
print(f"\nTheorem-4 all-UE weighted-equal-rate extreme [kHz]: "
      f"{(b_wer/1e3).round(1)} (Σ={b_wer.sum()/1e6:.3f} MHz)")
