"""Quickstart: train a personalized model with PerFedS² in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model

# 1) experiment config: 20 UEs, A=5 arrivals per round, staleness bound S=5
#    (the paper's Table I hyperparameters for MNIST)
cfg = ExperimentConfig(
    model=get_config("mnist_dnn"),
    fl=FLConfig(n_ues=20, participants_per_round=5, staleness_bound=5,
                alpha=0.03, beta=0.07,
                inner_batch=16, outer_batch=16, hessian_batch=16),
)

# 2) non-iid federated data: every UE holds l=4 of the 10 classes
model = build_model(cfg.model)
clients = partition_noniid(synthetic_mnist(n=4000), cfg.fl.n_ues, n_labels=4)

# 3) run the full system: wireless channels, Theorem-4 bandwidth, Alg.1
#    semi-synchronous server, Eq.-7 meta-gradients
result = run_simulation(cfg, model, clients, algorithm="perfed", mode="semi",
                        max_rounds=40, eval_every=10, verbose=True)

print(f"\nPerFedS² finished {result.rounds[-1]} rounds in "
      f"{result.total_time:.1f} simulated seconds")
print(f"personalized loss {result.losses[0]:.3f} → {result.losses[-1]:.3f}")
print(f"per-round participants (Π row sums): {set(result.pi.sum(1))}")
print(f"realised η: {result.eta_realised.round(3)}")
