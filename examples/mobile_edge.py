"""Mobile multi-cell PerFedS²: mobility, handovers, cell→cloud hierarchy.

Runs the same non-iid MNIST workload as ``quickstart.py`` in three regimes:

  static    — the paper's single frozen cell (mobility disabled)
  mobile    — one cell, vehicular random-waypoint UEs (time-varying
              path loss ⇒ mobility-induced stragglers)
  hierarchy — 3 cells with nearest-BS handover, per-cell semi-sync edge
              servers, and a cloud merge every 3 edge rounds

    PYTHONPATH=src python examples/mobile_edge.py [a.b=c overrides ...]
"""
from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          apply_overrides, parse_cli_overrides)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model

N_UES, ROUNDS = 24, 12


def main() -> None:
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=N_UES, participants_per_round=6,
                    staleness_bound=4, alpha=0.03, beta=0.07,
                    inner_batch=8, outer_batch=8, hessian_batch=8,
                    first_order=True))
    cfg = apply_overrides(cfg, parse_cli_overrides(sys.argv[1:]))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=2500, seed=0)

    # these demo runs last only a few simulated seconds; mobility
    # integrates on the step_s grid, so a sub-second tick keeps the UEs
    # visibly moving (and handing over) within the run
    regimes = {
        "static": cfg,
        "mobile": dataclasses.replace(cfg, mobility=MobilityConfig(
            enabled=True, model="random_waypoint", speed_mps=20.0,
            n_cells=1, step_s=0.1)),
        "hierarchy": dataclasses.replace(cfg, mobility=MobilityConfig(
            enabled=True, model="random_waypoint", speed_mps=40.0,
            n_cells=3, hierarchy=True, cloud_sync_every=3, step_s=0.1)),
    }

    for label, c in regimes.items():
        clients = partition_noniid(data, N_UES, n_labels=4, seed=0)
        res = run_simulation(c, model, clients, algorithm="perfed",
                             mode="semi", bandwidth_policy="equal",
                             max_rounds=ROUNDS, eval_every=4, seed=0,
                             name=label)
        print(f"[{label:9s}] cells={res.n_cells} "
              f"rounds={int(res.rounds[-1]) if len(res.rounds) else 0:3d} "
              f"sim_t={res.total_time:7.2f}s "
              f"handovers={res.handovers:3d} "
              f"cloud_merges={res.cloud_rounds} "
              f"final_ploss={res.losses[-1]:.4f} "
              f"wait={res.wait_fraction:.2f}")
        print(f"            realised η spread: "
              f"{np.ptp(res.eta_realised):.4f}")


if __name__ == "__main__":
    main()
