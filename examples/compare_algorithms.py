"""Fig. 3-style comparison: the paper's algorithm grid on one synthetic
non-iid task, reporting time-to-loss for each.

    PYTHONPATH=src python examples/compare_algorithms.py
"""
from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.algorithms import ALGORITHMS
from repro.fl.simulation import run_simulation
from repro.models import build_model

cfg = ExperimentConfig(
    model=get_config("mnist_dnn"),
    fl=FLConfig(n_ues=10, participants_per_round=3, staleness_bound=3,
                alpha=0.03, beta=0.07, inner_batch=16, outer_batch=16,
                hessian_batch=16))
model = build_model(cfg.model)
clients = partition_noniid(synthetic_mnist(n=3000), 10, n_labels=4)

print(f"{'algorithm':14s} {'rounds':>6s} {'sim time':>9s} "
      f"{'personalized':>12s} {'global':>8s}")
for name, (algo, mode) in ALGORITHMS.items():
    rounds = 20 if mode != "sync" else 6       # equalise gradient budget
    res = run_simulation(cfg, model, clients, algorithm=algo, mode=mode,
                         max_rounds=rounds, eval_every=rounds, seed=0)
    print(f"{name:14s} {res.rounds[-1]:6d} {res.total_time:8.2f}s "
          f"{res.losses[-1]:12.4f} {res.global_losses[-1]:8.4f}")

print("\nPerFedS2 should dominate the time-to-personalized-loss frontier;")
print("*-SYN rows pay straggler wall-clock, *-ASY rows pay gradient staleness.")
