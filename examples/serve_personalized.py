"""Serve a small model with batched requests — with per-user personalization.

Per-FedAvg's deployment story: the trained meta-initialisation is adapted
with ONE gradient step on each user's data before serving.  This example
serves two users whose "dialects" differ (different token statistics) and
shows the adapted models' losses beating the shared meta model on each
user's own stream.

    PYTHONPATH=src python examples/serve_personalized.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.perfed import adapt
from repro.data.synthetic import synthetic_lm_corpus
from repro.models import build_model

cfg = dataclasses.replace(get_config("yi_6b").reduced(), vocab_size=512)
model = build_model(cfg)
rng = jax.random.PRNGKey(0)
params = model.init(rng)

# two users with different bigram statistics
users = [synthetic_lm_corpus(4096, vocab=cfg.vocab_size, seed=s)
         for s in (10, 11)]

def batch_from(corpus, n=8, sl=64, off=0):
    toks = np.stack([corpus[i * sl + off:(i + 1) * sl + off]
                     for i in range(n)])
    targ = np.stack([corpus[i * sl + 1 + off:(i + 1) * sl + 1 + off]
                     for i in range(n)])
    return {"tokens": jnp.asarray(toks), "targets": jnp.asarray(targ)}

loss_fn = jax.jit(lambda p, b: model.loss(p, b)[0])
print(f"{'user':>4s} {'meta loss':>10s} {'adapted':>10s}")
adapted_params = []
for ui, corpus in enumerate(users):
    support = batch_from(corpus, off=0)
    query = batch_from(corpus, off=2048)
    l_meta = float(loss_fn(params, query))
    p_ad = adapt(model.loss, params, support, alpha=0.05)
    adapted_params.append(p_ad)
    l_ad = float(loss_fn(p_ad, query))
    print(f"{ui:4d} {l_meta:10.4f} {l_ad:10.4f}")

# batched serving loop with the personalized weights
prefill = jax.jit(lambda p, t: model.prefill(p, t, 128))
decode = jax.jit(model.decode_step)
prompts = batch_from(users[0], n=4, sl=32)["tokens"]
t0 = time.time()
logits, cache = prefill(adapted_params[0], prompts)
tok = jnp.argmax(logits, -1).reshape(4, 1).astype(jnp.int32)
out = [tok]
for i in range(15):
    logits, cache = decode(adapted_params[0], cache, tok, jnp.int32(32 + i))
    tok = jnp.argmax(logits, -1).reshape(4, 1).astype(jnp.int32)
    out.append(tok)
jax.block_until_ready(tok)
gen = jnp.concatenate(out, 1)
print(f"\nbatched serve: 4 requests × 16 tokens in {time.time()-t0:.2f}s")
print("sample:", np.asarray(gen)[0].tolist())
