"""Heterogeneous per-cell radio resources: macro/micro budgets, Theorem-2
allocation in the mobile loop, and load-aware association.

One macro BS (2 MHz) plus two micro BSs (0.5 MHz each) serve vehicular
random-waypoint UEs.  Four regimes compare the new knobs:

  nearest/equal      — legacy: nearest-BS association, even per-cell split
  nearest/theorem2   — per-cell equal-finish bisection (paper Thm. 2),
                       warm-started from each cell's previous t*
  load_aware/equal   — hot (or skinny-budget) cells shed UEs to neighbours
  load_aware/theorem2— both: the full heterogeneous-resource stack

    PYTHONPATH=src python examples/hetero_cells.py [a.b=c overrides ...]
"""
from __future__ import annotations

import dataclasses
import sys


from repro.config import (ExperimentConfig, FLConfig, MobilityConfig,
                          apply_overrides, parse_cli_overrides)
from repro.configs import get_config
from repro.data import partition_noniid, synthetic_mnist
from repro.fl.simulation import run_simulation
from repro.models import build_model

N_UES, ROUNDS = 24, 12
BUDGETS = (2e6, 5e5, 5e5)            # macro + two micros [Hz]


def main() -> None:
    cfg = ExperimentConfig(
        model=get_config("mnist_dnn"),
        fl=FLConfig(n_ues=N_UES, participants_per_round=6,
                    staleness_bound=4, alpha=0.03, beta=0.07,
                    inner_batch=8, outer_batch=8, hessian_batch=8,
                    first_order=True, eta_mode="distance"))
    cfg = apply_overrides(cfg, parse_cli_overrides(sys.argv[1:]))
    model = build_model(cfg.model)
    data = synthetic_mnist(n=2500, seed=0)

    for assoc in ("nearest", "load_aware"):
        for policy in ("equal", "theorem2"):
            c = dataclasses.replace(cfg, mobility=MobilityConfig(
                enabled=True, model="random_waypoint", speed_mps=30.0,
                n_cells=3, hierarchy=True, cloud_sync_every=4,
                cell_bandwidth_hz=BUDGETS, association=assoc))
            clients = partition_noniid(data, N_UES, n_labels=4, seed=0)
            res = run_simulation(c, model, clients, algorithm="perfed",
                                 mode="semi", bandwidth_policy=policy,
                                 max_rounds=ROUNDS, eval_every=4, seed=0,
                                 name=f"{assoc}/{policy}")
            rounds = max(int(res.pi.shape[0]), 1)
            print(f"[{assoc:10s}/{policy:8s}] "
                  f"rounds={rounds:3d} "
                  f"sim_round={res.total_time / rounds:6.3f}s "
                  f"handovers={res.handovers:3d} "
                  f"final_ploss={res.losses[-1]:.4f} "
                  f"wait={res.wait_fraction:.2f}")


if __name__ == "__main__":
    main()
