"""End-to-end driver: PerFed semi-synchronous training of a transformer LM
across simulated client cohorts — the datacenter-scale mapping of Alg. 1.

Default runs a ~8M-param Yi-family model for 60 rounds on CPU (minutes);
``--model-scale 100m`` trains a ~100M-param variant (slower), and the FULL
assigned configs are exercised by the dry-run (see launch/dryrun.py).

    PYTHONPATH=src python examples/train_e2e.py --rounds 60
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.config import ExperimentConfig, FLConfig, TrainConfig
from repro.configs import get_config
from repro.core import semi_sync
from repro.core.scheduler import greedy_schedule, relative_frequencies
from repro.data.synthetic import synthetic_lm_corpus
from repro.models import build_model
from repro.optim import make_optimizer


def model_cfg(scale: str):
    base = get_config("yi_6b")
    if scale == "100m":
        return dataclasses.replace(
            base, name="yi-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, d_ff=2048, vocab_size=8192, remat=False)
    return dataclasses.replace(
        base, name="yi-8m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=1024, vocab_size=2048, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--participants", type=int, default=2)   # A
    ap.add_argument("--staleness", type=int, default=2)      # S
    ap.add_argument("--model-scale", default="8m", choices=["8m", "100m"])
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--server-opt", default="sgd",
                    choices=["sgd", "momentum", "adam"])
    ap.add_argument("--fused-agg", action="store_true",
                    help="disable grad clipping so the round update takes "
                         "the fused Eq.-(8) stale_aggregate path (β-SGD)")
    args = ap.parse_args()
    if args.fused_agg and args.server_opt != "sgd":
        ap.error("--fused-agg requires --server-opt sgd (the fused Eq.-8 "
                 "path is the plain β-SGD update)")

    mcfg = model_cfg(args.model_scale)
    cfg = ExperimentConfig(
        model=mcfg,
        fl=FLConfig(alpha=0.02, beta=0.5, staleness_bound=args.staleness,
                    algorithm="perfed"),
        train=TrainConfig(grad_clip=0.0 if args.fused_agg else 1.0))
    model = build_model(mcfg)
    opt = make_optimizer(args.server_opt)
    n = args.cohorts

    step_fn = jax.jit(semi_sync.make_semi_sync_step(model, cfg, opt, n))
    rng = jax.random.PRNGKey(0)
    state = semi_sync.init_state(model, rng, opt, n)
    nparams = sum(int(x.size) for x in jax.tree.leaves(state.params))
    agg_path = ("fused stale_aggregate (Eq. 8)"
                if semi_sync.uses_fused_eq8(opt, cfg)
                else f"masked mean + {opt.name}")
    print(f"model {mcfg.name}: {nparams/1e6:.1f}M params, "
          f"{n} cohorts, A={args.participants}, S={args.staleness}, "
          f"aggregation: {agg_path}")

    # per-cohort non-iid corpora (different synthetic seeds = different
    # "client populations"); Alg.-2 schedule over the cohorts
    corpora = [synthetic_lm_corpus(1 << 15, vocab=mcfg.vocab_size, seed=i)
               for i in range(n)]
    eta = relative_frequencies(n, "equal")
    pi = greedy_schedule(eta, args.participants, args.rounds)

    def cohort_batch(r, kind_seed):
        def one(ci, rr):
            c = corpora[ci]
            starts = jax.random.randint(rr, (args.batch,), 0,
                                        len(c) - args.seq - 1)
            toks = jnp.stack([jnp.asarray(c[s:s + args.seq]) for s in starts])
            targ = jnp.stack([jnp.asarray(c[s + 1:s + args.seq + 1])
                              for s in starts])
            return {"tokens": toks, "targets": targ}
        rs = jax.random.split(jax.random.fold_in(r, kind_seed), n)
        batches = [one(ci, rs[ci]) for ci in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    eval_model = jax.jit(lambda p, b: model.loss(p, b)[0])
    t0 = time.time()
    for k in range(args.rounds):
        rng, r = jax.random.split(rng)
        batches = {"inner": cohort_batch(r, 1), "outer": cohort_batch(r, 2),
                   "hessian": cohort_batch(r, 3)}
        mask = jnp.asarray(pi[k], jnp.float32)
        state, metrics = step_fn(state, batches, mask, r)
        if k % max(1, args.rounds // 10) == 0 or k == args.rounds - 1:
            eb = jax.tree.map(lambda x: x[0], batches["outer"])
            loss = float(eval_model(state.params, eb))
            print(f"round {k:4d} mask={pi[k]} loss={loss:.4f} "
                  f"max_stale={int(metrics['max_staleness'])} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt_dir:
        print("saved", save_checkpoint(args.ckpt_dir, state.params,
                                       step=args.rounds))


if __name__ == "__main__":
    main()
