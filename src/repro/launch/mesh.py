"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — device count is locked at first jax init, and the
dry-run needs to set XLA_FLAGS before that happens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small host-device meshes like (2, 4))."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pods: Optional[int] = None):
    """Mesh over however many host devices exist (CPU test path)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
