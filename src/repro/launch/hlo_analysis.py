"""Trip-count-aware HLO analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so for
scan-over-layers models the reported FLOPs/bytes understate the true per-step
work by ~num_layers×.  This module parses ``compiled.as_text()`` and
re-derives, with ``known_trip_count`` weighting applied along the call graph:

  * dot_flops          — 2·(result elements)·K per dot, exact for matmuls
                         (the dominant term in every model here)
  * bytes_estimate     — Σ result-buffer bytes per instruction (a proxy for
                         HBM traffic; fusion makes the true number smaller,
                         so treat as an upper-ish bound)
  * collective_bytes   — per-kind result bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute

All numbers are per-device (the HLO is the SPMD per-device module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([\d,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _numel_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape_str: str          # result type text (may be a tuple)
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    # symbol table: %name -> result shape text
    shapes: Dict[str, str] = field(default_factory=dict)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and \
                    (s.startswith("%") or s.startswith("ENTRY")):
                m = _COMP_HDR.match(s)
                if m:
                    name = m.group(1).lstrip("%")
                    cur = Computation(name)
                    if s.startswith("ENTRY"):
                        entry = name
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(s)
        if m:
            name, shape_str, op = m.group(1), m.group(2), m.group(3)
            cur.instrs.append(Instr(name, shape_str, op, s))
            cur.shapes[name] = shape_str
        elif "parameter(" in s:
            m2 = re.match(r"^\s*(%[\w.\-]+)\s*=\s*(.+?)\s+parameter\(", s)
            if m2:
                cur.instrs.append(Instr(m2.group(1), m2.group(2),
                                        "parameter", s))
                cur.shapes[m2.group(1)] = m2.group(2)
    return comps, entry


_CALLEE_RE = {
    "while": re.compile(r"body=(%?[\w.\-]+)"),
    "cond": re.compile(r"condition=(%?[\w.\-]+)"),
    "fusion": re.compile(r"calls=(%?[\w.\-]+)"),
    "call": re.compile(r"to_apply=(%?[\w.\-]+)"),
    "conditional": re.compile(r"(?:true_computation|branch_computations)="
                              r"[{(]?(%?[\w.\-]+)"),
    "sort": re.compile(r"to_apply=(%?[\w.\-]+)"),
    "reduce": re.compile(r"to_apply=(%?[\w.\-]+)"),
    "scatter": re.compile(r"to_apply=(%?[\w.\-]+)"),
}

_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n["\':]+\s*"?(\d+)')


def _dot_flops(instr: Instr, comp: Computation) -> float:
    """2 · numel(result) · K  (K = product of lhs contracting dim sizes)."""
    shapes = _shapes_in(instr.shape_str)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    numel = 1
    for d in rdims:
        numel *= d
    m = re.search(r"dot\((%[\w.\-]+)", instr.line)
    mc = re.search(r"lhs_contracting_dims={([\d,]*)}", instr.line)
    if not m or not mc:
        return 2.0 * numel          # fallback: treat as elementwise-ish
    lhs_shape_str = comp.shapes.get(m.group(1), "")
    lsh = _shapes_in(lhs_shape_str)
    if not lsh:
        return 2.0 * numel
    _, ldims = lsh[0]
    k = 1
    for ci in mc.group(1).split(","):
        if ci != "" and int(ci) < len(ldims):
            k *= ldims[int(ci)]
    return 2.0 * numel * k


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy-start", "copy-done", "after-all"}


def analyze_hlo(text: str) -> Dict[str, object]:
    comps, entry = parse_hlo(text)
    if entry is None:
        for name in comps:
            if name.startswith("main") or "entry" in name.lower():
                entry = name
                break
        if entry is None and comps:
            entry = next(iter(comps))

    memo: Dict[str, Dict[str, object]] = {}

    def visit(name: str) -> Dict[str, object]:
        name = name.lstrip("%")
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        acc = {"dot_flops": 0.0, "bytes": 0.0,
               "coll": {k: 0.0 for k in _COLLECTIVES},
               "coll_count": {k: 0 for k in _COLLECTIVES}}
        memo[name] = acc             # break cycles defensively
        if comp is None:
            return acc
        for ins in comp.instrs:
            base_op = ins.op.replace("-start", "").replace("-done", "")
            if base_op == "dot":
                acc["dot_flops"] += _dot_flops(ins, comp)
            if ins.op not in _SKIP_BYTES_OPS and not ins.op.endswith("-done"):
                acc["bytes"] += _numel_bytes(ins.shape_str)
            if base_op in _COLLECTIVES and not ins.op.endswith("-done"):
                acc["coll"][base_op] += _numel_bytes(ins.shape_str)
                acc["coll_count"][base_op] += 1
            # recurse into callees
            mult = 1.0
            callees: List[str] = []
            if ins.op == "while":
                mb = _CALLEE_RE["while"].search(ins.line)
                mt = _TRIP_RE.search(ins.line)
                mult = float(mt.group(1)) if mt else 1.0
                if mb:
                    callees.append(mb.group(1))
                mc = _CALLEE_RE["cond"].search(ins.line)
                if mc:
                    callees.append(mc.group(1))
            elif ins.op == "fusion":
                mb = _CALLEE_RE["fusion"].search(ins.line)
                if mb:
                    callees.append(mb.group(1))
            elif ins.op in ("call", "custom-call", "sort", "reduce",
                            "reduce-window", "scatter", "select-and-scatter",
                            "map", "conditional", "async-start"):
                for pat_key in ("call", "conditional"):
                    mb = _CALLEE_RE[pat_key].search(ins.line)
                    if mb:
                        callees.append(mb.group(1))
                        break
            for callee in callees:
                sub = visit(callee)
                acc["dot_flops"] += mult * sub["dot_flops"]
                acc["bytes"] += mult * sub["bytes"]
                for k in _COLLECTIVES:
                    acc["coll"][k] += mult * sub["coll"][k]
                    acc["coll_count"][k] += int(mult) * sub["coll_count"][k]
        return acc

    acc = visit(entry) if entry else {"dot_flops": 0.0, "bytes": 0.0,
                                      "coll": {}, "coll_count": {}}
    return {
        "dot_flops_tc": acc["dot_flops"],
        "bytes_estimate_tc": acc["bytes"],
        "collective_bytes_tc": dict(acc["coll"]),
        "collective_count_tc": dict(acc["coll_count"]),
        "collective_total_tc": sum(acc["coll"].values()),
        "n_computations": len(comps),
    }
