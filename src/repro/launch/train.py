"""End-to-end training launcher.

Two modes:

* ``--mode fl``     (default) — the paper: event-driven PerFedS² simulation
  over a mobile edge network with the paper's small models + synthetic
  federated datasets.  Runs for real on CPU.
* ``--mode scale``  — datacenter path: PerFed semi-sync step on an assigned
  LLM architecture over a device mesh (reduced sizes run on host devices;
  full sizes are exercised by ``dryrun.py``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode fl --arch mnist_dnn \
      --algo perfed --sync-mode semi fl.rounds=50
  PYTHONPATH=src python -m repro.launch.train --mode scale --arch yi_6b \
      --reduce --steps 20
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import replace



def main(argv=None):
    ap = argparse.ArgumentParser(description="PerFedS² training launcher")
    ap.add_argument("--mode", default="fl", choices=["fl", "scale"])
    ap.add_argument("--arch", default="mnist_dnn")
    ap.add_argument("--algo", default="perfed",
                    choices=["perfed", "fedavg", "fedprox"])
    ap.add_argument("--sync-mode", default="semi",
                    choices=["sync", "semi", "async"])
    ap.add_argument("--bandwidth", default="optimal",
                    choices=["optimal", "equal"])
    ap.add_argument("--noniid-l", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduce", action="store_true",
                    help="scale mode: reduced model for CPU execution")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--metrics-dir", default="",
                    help="write metrics.jsonl under this directory")
    ap.add_argument("overrides", nargs="*", help="dotted config overrides")
    args = ap.parse_args(argv)

    from repro.config import ExperimentConfig, apply_overrides, parse_cli_overrides
    from repro.configs import get_config

    cfg = ExperimentConfig(model=get_config(args.arch))
    cfg = apply_overrides(cfg, parse_cli_overrides(args.overrides))

    if args.mode == "fl":
        return run_fl(cfg, args)
    return run_scale(cfg, args)


def run_fl(cfg, args):
    import jax
    from repro.data import (partition_noniid, synthetic_cifar, synthetic_mnist,
                            synthetic_shakespeare)
    from repro.data.partition import sequence_clients
    from repro.fl.simulation import run_simulation
    from repro.models import build_model

    model = build_model(cfg.model)
    name = cfg.model.name
    if name.startswith("char_lstm"):
        role_data = synthetic_shakespeare(n_roles=cfg.fl.n_ues)
        clients = sequence_clients(role_data, cfg.fl.n_ues, seed=args.seed)
    elif name.startswith("lenet5"):
        data = synthetic_cifar(n=4000)
        clients = partition_noniid(data, cfg.fl.n_ues, n_labels=args.noniid_l,
                                   seed=args.seed)
    else:
        data = synthetic_mnist(n=4000)
        clients = partition_noniid(data, cfg.fl.n_ues, n_labels=args.noniid_l,
                                   seed=args.seed)

    res = run_simulation(cfg, model, clients, algorithm=args.algo,
                         mode=args.sync_mode, bandwidth_policy=args.bandwidth,
                         seed=args.seed, verbose=True)
    if args.metrics_dir:
        from repro.utils.metrics import MetricsLogger
        with MetricsLogger(args.metrics_dir,
                           meta={"arch": args.arch, "algo": args.algo,
                                 "mode": args.sync_mode}) as log:
            for i in range(len(res.times)):
                log.log(step=int(res.rounds[i]), sim_t=float(res.times[i]),
                        ploss=float(res.losses[i]),
                        gloss=float(res.global_losses[i]))
    print(f"\nfinal: t={res.total_time:.2f}s rounds={res.rounds[-1]} "
          f"personalized_loss={res.losses[-1]:.4f} "
          f"global_loss={res.global_losses[-1]:.4f} "
          f"wait_frac={res.wait_fraction:.3f}")
    return 0


def run_scale(cfg, args):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import save_checkpoint
    from repro.core import semi_sync
    from repro.models import build_model
    from repro.optim import make_optimizer

    mcfg = cfg.model.reduced() if args.reduce else cfg.model
    model = build_model(mcfg)
    optimizer = make_optimizer("sgd")
    step_fn = jax.jit(semi_sync.make_train_step(model, replace(cfg, model=mcfg),
                                                optimizer, perfed_step=True))
    rng = jax.random.PRNGKey(args.seed)
    state = semi_sync.init_train_state(model, rng, optimizer)

    from repro.data.synthetic import synthetic_lm_corpus
    corpus = synthetic_lm_corpus(n_tokens=1 << 15, vocab=mcfg.vocab_size)
    seq, bsz = 64, 8

    def batch(r):
        starts = jax.random.randint(r, (bsz,), 0, len(corpus) - seq - 1)
        toks = jnp.stack([jnp.asarray(corpus[s:s + seq]) for s in starts])
        targ = jnp.stack([jnp.asarray(corpus[s + 1:s + seq + 1]) for s in starts])
        if mcfg.family == "audio":
            toks = jnp.tile(toks[..., None] % mcfg.vocab_size,
                            (1, 1, mcfg.num_audio_codebooks))
            targ = jnp.tile(targ[..., None] % mcfg.vocab_size,
                            (1, 1, mcfg.num_audio_codebooks))
        return {"tokens": toks, "targets": targ}

    t0 = time.time()
    for step in range(args.steps):
        rng, r1, r2, r3, r4 = jax.random.split(rng, 5)
        batches = {"inner": batch(r1), "outer": batch(r2), "hessian": batch(r3)}
        state, metrics = step_fn(state, batches, r4)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt_dir:
        f = save_checkpoint(args.ckpt_dir, state.params, step=args.steps)
        print("saved", f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
