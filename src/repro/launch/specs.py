"""Abstract input/state specs for lowering (ShapeDtypeStruct — no allocation).

``build_case(arch, shape, mesh, ...)`` returns everything ``dryrun.py`` needs:
the jittable step function, abstract arguments, and in/out shardings.

Sharding policy (resolved per-arch by divisibility):
  params        2-D sharded by repro.sharding rules (feature→model, embed→data)
  batch dims    → ("pod","data")
  decode caches → heads→model if divisible else seq→model; batch→data if
                  divisible else left whole
  semi-sync cohort buffers → cohort axis on "pod"
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding
from repro.config import (ExperimentConfig, FLConfig, ModelConfig,
                          ShapeConfig, TrainConfig)
from repro.core import semi_sync
from repro.models import build_model
from repro.optim import make_optimizer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n > 0 and n % k == 0


def arch_rules(cfg: ModelConfig, mesh: Mesh) -> sharding.AxisRules:
    """Per-arch rule overrides driven by divisibility constraints."""
    rules = sharding.AxisRules()
    msize = mesh.shape.get("model", 1)
    over = {}
    if cfg.moe is not None and not _divides(cfg.moe.num_experts, msize):
        # too few experts for the model axis (mixtral 8e on 16): let the
        # expert FFN dim take the model axis instead (dense-TP style)
        over["experts"] = ()
    if cfg.vocab_size and not _divides(cfg.vocab_size, msize):
        over["vocab"] = ()
    if over:
        rules = rules.with_overrides(**over)
    return rules


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Physical axes carrying the batch dim — honours the active rule set
    (pure-DP setups map batch over the model axis too)."""
    cand = sharding.active_rules().rules.get("batch", ("pod", "data"))
    return tuple(a for a in cand if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# abstract batches
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, triplet: bool = True, n_cohorts: int = 0):
    """Abstract train batch (the Eq.-7 triplet), plus NamedShardings."""
    b, s = shape.global_batch, shape.seq_len
    lead = (n_cohorts, b // max(n_cohorts, 1)) if n_cohorts else (b,)
    tok_shape = lead + (s,)
    if cfg.family == "audio":
        tok_shape = tok_shape + (cfg.num_audio_codebooks,)

    def one_batch():
        d = {"tokens": _sds(tok_shape, jnp.int32),
             "targets": _sds(tok_shape, jnp.int32)}
        if cfg.family == "vlm":
            img = lead + (cfg.num_image_tokens, cfg.d_model)
            d["image_embeds"] = _sds(img, jnp.dtype(cfg.dtype))
        return d

    batch = ({"inner": one_batch(), "outer": one_batch(),
              "hessian": one_batch()} if triplet else one_batch())

    ba = batch_axes(mesh)
    if n_cohorts:
        # cohort → pod, per-cohort batch → data
        def spec_for(leaf):
            rest = (None,) * (len(leaf.shape) - 2)
            return NamedSharding(mesh, P("pod", "data", *rest))
    else:
        def spec_for(leaf):
            rest = (None,) * (len(leaf.shape) - 1)
            return NamedSharding(mesh, P(ba, *rest))
    shardings = jax.tree.map(spec_for, batch)
    return batch, shardings


def decode_inputs_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    b = shape.global_batch
    tok_shape = (b, 1) if cfg.family != "audio" \
        else (b, 1, cfg.num_audio_codebooks)
    tokens = _sds(tok_shape, jnp.int32)
    pos = _sds((), jnp.int32)
    ba = batch_axes(mesh)
    tok_spec = NamedSharding(
        mesh, P(ba, *([None] * (len(tok_shape) - 1)))) \
        if _divides(b, int(np.prod([mesh.shape[a] for a in ba]))) \
        else NamedSharding(mesh, P(*([None] * len(tok_shape))))
    return tokens, pos, tok_spec, NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# cache sharding
# ---------------------------------------------------------------------------

def cache_shardings(cache_abs, mesh: Mesh, batch: int,
                    policy: str = "auto"):
    """Assign NamedShardings to an abstract cache pytree by leaf path.

    ``policy="replicate"``: keep the whole cache replicated — for tiny-batch
    long-context decode this trades per-device memory for ZERO cache
    collectives (§Perf lever for the collective-bound long_500k cases).
    """
    dsize = mesh.shape.get("data", 1)
    msize = mesh.shape.get("model", 1)
    batch_ok = _divides(batch, dsize)
    if policy == "replicate":
        return jax.tree.map(
            lambda leaf: NamedSharding(mesh, P(*([None] * leaf.ndim))),
            cache_abs)

    def assign(path, leaf):
        name = sharding._path_str(path)
        dims: list = [None] * leaf.ndim
        # layout conventions (see models/*.init_cache):
        #   k/v   [L, B, S, H, D]      pos [L, B, S]
        #   ckv   [L, B, S, R]         kr  [L, B, S, R]
        #   conv  [L, B, W, C]         state [L, B, H, P, N]   h [L, B, W]
        if leaf.ndim >= 2 and batch_ok:
            dims[1] = "data"
        key = name.split("/")[-1]
        if key in ("k", "v") and leaf.ndim == 5:
            if _divides(leaf.shape[3], msize):
                dims[3] = "model"
            elif _divides(leaf.shape[2], msize):
                dims[2] = "model"
        elif key in ("ckv", "kr") and leaf.ndim == 4:
            if _divides(leaf.shape[2], msize):
                dims[2] = "model"
        elif key == "pos":
            pass
        elif key == "conv" and leaf.ndim == 4:
            if _divides(leaf.shape[3], msize):
                dims[3] = "model"
        elif key == "state" and leaf.ndim == 5:
            if _divides(leaf.shape[2], msize):
                dims[2] = "model"
            elif _divides(leaf.shape[3], msize):
                dims[3] = "model"
        elif key == "h" and leaf.ndim == 3:
            if _divides(leaf.shape[2], msize):
                dims[2] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(assign, cache_abs)


# ---------------------------------------------------------------------------
# state sharding
# ---------------------------------------------------------------------------

def state_shardings(state_abs, params_shardings, mesh: Mesh):
    """Shardings for TrainState / SemiSyncState given the params' shardings."""
    def like_params(tree):
        return tree

    if isinstance(state_abs, semi_sync.SemiSyncState):
        # buffers: cohort leading dim → pod, rest like params
        def buf_spec(ps):
            spec = ps.spec if isinstance(ps, NamedSharding) else P()
            lead = "pod" if "pod" in mesh.axis_names else None
            return NamedSharding(mesh, P(lead, *spec))
        buf_sh = jax.tree.map(buf_spec, params_shardings)
        opt_sh = _opt_shardings(state_abs.opt_state, params_shardings, mesh)
        return semi_sync.SemiSyncState(
            params=params_shardings,
            opt_state=opt_sh,
            buffers=buf_sh,
            staleness=NamedSharding(mesh, P(None)),
            step=NamedSharding(mesh, P()),
        )
    # TrainState
    opt_sh = _opt_shardings(state_abs.opt_state, params_shardings, mesh)
    return semi_sync.TrainState(
        params=params_shardings,
        opt_state=opt_sh,
        step=NamedSharding(mesh, P()),
    )


def _opt_shardings(opt_abs, params_shardings, mesh: Mesh):
    if isinstance(opt_abs, tuple) and len(opt_abs) == 0:
        return ()
    out = {}
    for key, sub in opt_abs.items():
        if key in ("m", "v"):
            out[key] = params_shardings
        else:
            out[key] = jax.tree.map(lambda _: NamedSharding(mesh, P()), sub)
    return out


# ---------------------------------------------------------------------------
# case builder
# ---------------------------------------------------------------------------

class LowerCase(NamedTuple):
    name: str
    fn: Callable            # jittable
    args: Tuple             # abstract args
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]


def build_case(model_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               fl: Optional[FLConfig] = None,
               train: Optional[TrainConfig] = None,
               moe_impl: str = "gather",
               semi_sync_cohorts: Optional[int] = None,
               perfed_step: bool = True,
               cache_policy: str = "auto",
               rules: Optional[sharding.AxisRules] = None,
               seed: int = 0) -> LowerCase:
    """Assemble one (arch × shape × mesh) lowering case."""
    fl = fl or FLConfig()
    train = train or TrainConfig(seq_len=shape.seq_len,
                                 global_batch_size=shape.global_batch)
    cfg = dataclasses.replace(model_cfg, max_seq_len=max(model_cfg.max_seq_len,
                                                         shape.seq_len))
    exp = ExperimentConfig(model=cfg, fl=fl, train=train)
    model = build_model(cfg, moe_impl=moe_impl)
    rules = rules or arch_rules(cfg, mesh)

    rng = jax.random.PRNGKey(seed)
    with sharding.use_mesh(None):   # abstract init never needs the mesh
        params_abs = jax.eval_shape(model.init, rng)
    pspecs = sharding.param_specs(params_abs, mesh, rules)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda s: isinstance(s, P))

    meta = {"arch": cfg.name, "shape": shape.name, "mesh": dict(mesh.shape),
            "kind": shape.kind}

    if shape.kind == "train":
        optimizer = make_optimizer("sgd")   # Alg.-1 server = β-SGD (faithful)
        if semi_sync_cohorts and semi_sync_cohorts > 1:
            step = semi_sync.make_semi_sync_step(model, exp, optimizer,
                                                 semi_sync_cohorts)
            state_abs = jax.eval_shape(
                functools.partial(semi_sync.init_state, model,
                                  optimizer=optimizer,
                                  n_cohorts=semi_sync_cohorts), rng)
            batch_abs, batch_sh = train_batch_specs(
                cfg, shape, mesh, triplet=True, n_cohorts=semi_sync_cohorts)
            mask_abs = _sds((semi_sync_cohorts,), jnp.float32)
            args = (state_abs, batch_abs, mask_abs, rng)
            st_sh = state_shardings(state_abs, psh, mesh)
            in_sh = (st_sh, batch_sh, NamedSharding(mesh, P(None)),
                     NamedSharding(mesh, P()))
            out_sh = (st_sh, jax.tree.map(
                lambda _: NamedSharding(mesh, P()),
                {"grad_norm": 0, "participants": 0, "max_staleness": 0}))
            name = f"{cfg.name}:{shape.name}:semi_sync"
        else:
            step = semi_sync.make_train_step(model, exp, optimizer,
                                             perfed_step=perfed_step)
            state_abs = jax.eval_shape(
                functools.partial(semi_sync.init_train_state, model,
                                  optimizer=optimizer), rng)
            batch_abs, batch_sh = train_batch_specs(cfg, shape, mesh,
                                                    triplet=True)
            args = (state_abs, batch_abs, rng)
            st_sh = state_shardings(state_abs, psh, mesh)
            in_sh = (st_sh, batch_sh, NamedSharding(mesh, P()))
            out_sh = (st_sh, {"loss": NamedSharding(mesh, P()),
                              "grad_norm": NamedSharding(mesh, P())})
            name = f"{cfg.name}:{shape.name}:perfed" if perfed_step \
                else f"{cfg.name}:{shape.name}:plain"
        return LowerCase(name, step, args, in_sh, out_sh, meta)

    if shape.kind == "prefill":
        batch_abs, batch_sh = train_batch_specs(cfg, shape, mesh,
                                                triplet=False)
        cache_len = min(shape.seq_len, _cache_len(cfg, shape))

        def prefill_fn(params, tokens, image_embeds=None):
            kw = {}
            if cfg.family == "vlm":
                kw["image_embeds"] = image_embeds
            return model.prefill(params, tokens, cache_len, **kw)

        args = [params_abs, batch_abs["tokens"]]
        in_sh = [psh, batch_sh["tokens"]]
        if cfg.family == "vlm":
            args.append(batch_abs["image_embeds"])
            in_sh.append(batch_sh["image_embeds"])
        cache_abs = jax.eval_shape(
            lambda p, t, *i: prefill_fn(p, t, *i)[1], *args)
        csh = cache_shardings(cache_abs, mesh, shape.global_batch)
        ba = batch_axes(mesh)
        logit_sh = NamedSharding(mesh, P(ba, None, None)) \
            if cfg.family != "audio" else NamedSharding(mesh, P(ba, None, None, None))
        out_sh = (logit_sh, csh)
        return LowerCase(f"{cfg.name}:{shape.name}:prefill", prefill_fn,
                         tuple(args), tuple(in_sh), out_sh, meta)

    # decode
    tokens_abs, pos_abs, tok_sh, pos_sh = decode_inputs_specs(cfg, shape, mesh)
    cache_len = _cache_len(cfg, shape)
    window = _decode_window(cfg, shape)
    cache_abs = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, cache_len))
    csh = cache_shardings(cache_abs, mesh, shape.global_batch,
                          policy=cache_policy)

    def decode_fn(params, cache, tokens, pos):
        kw = {"window": window} if window is not None else {}
        if cfg.family == "vlm":
            kw["image_embeds"] = _vlm_img_abs(cfg, shape.global_batch)
        return model.decode_step(params, cache, tokens, pos, **kw)

    if cfg.family == "vlm":
        def decode_fn(params, cache, tokens, pos, img):  # noqa: F811
            kw = {"window": window} if window is not None else {}
            return model.decode_step(params, cache, tokens, pos,
                                     image_embeds=img, **kw)

    args = [params_abs, cache_abs, tokens_abs, pos_abs]
    in_sh = [psh, csh, tok_sh, pos_sh]
    if cfg.family == "vlm":
        img_abs = _sds((shape.global_batch, cfg.num_image_tokens, cfg.d_model),
                       jnp.dtype(cfg.dtype))
        args.append(img_abs)
        ba = batch_axes(mesh)
        bdim = ba if _divides(shape.global_batch,
                              int(np.prod([mesh.shape[a] for a in ba]))) else None
        in_sh.append(NamedSharding(mesh, P(bdim, None, None)))
    logit_sh = tok_sh if cfg.family != "audio" else NamedSharding(
        mesh, P(*tok_sh.spec, None))
    out_sh = (NamedSharding(mesh, P(*((None,) * (2 if cfg.family != "audio"
                                                 else 3)))), csh)
    return LowerCase(f"{cfg.name}:{shape.name}:decode", decode_fn,
                     tuple(args), tuple(in_sh), out_sh, meta)


def _cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV-cache length: full history for decode_32k; the sliding window for
    long_500k (sub-quadratic memory — full 524k cache is never materialised
    for attention archs; SSM/hybrid have O(1) state anyway)."""
    if cfg.family in ("ssm",):
        return 0
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    if shape.seq_len > 65536:
        return cfg.long_context_window
    return shape.seq_len


def _decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    if cfg.family in ("ssm", "hybrid"):
        return None
    if cfg.sliding_window:
        return None                      # model already windows natively
    if shape.seq_len > 65536:
        return cfg.long_context_window   # sliding-window long-context variant
    return None


def _vlm_img_abs(cfg, batch):
    return _sds((batch, cfg.num_image_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
