"""Serving launcher: batched prefill + decode with a (optionally personalized)
model.  Runs reduced configs for real on CPU; full configs lower via dryrun.

The PFL twist: ``--personalize`` adapts the served weights with one inner
SGD step on a provided "user" batch before serving — the deployment story of
Per-FedAvg (every user serves their own fine-tuned model from the meta
initialisation).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --reduce \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(description="batched serving driver")
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--full", dest="reduce", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--personalize", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.perfed import adapt
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    b, lp = args.batch, args.prompt_len

    tok_shape = (b, lp) if cfg.family != "audio" \
        else (b, lp, cfg.num_audio_codebooks)
    prompts = jax.random.randint(rng, tok_shape, 0, cfg.vocab_size)

    if args.personalize:
        targ = jnp.roll(prompts, -1, axis=1)
        user_batch = {"tokens": prompts, "targets": targ}
        params = adapt(model.loss, params, user_batch, alpha=0.01, rng=rng)
        print("personalized: one inner-SGD adaptation step applied")

    prefill = jax.jit(lambda p, t: model.prefill(p, t, args.cache_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cfg.family == "audio":
        toks = toks.reshape(b, 1, -1)
    else:
        toks = toks.reshape(b, 1)
    out_tokens = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, toks, jnp.int32(lp + i))
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks = toks.reshape(b, 1, -1) if cfg.family == "audio" \
            else toks.reshape(b, 1)
        out_tokens.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={lp} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(args.gen-1,1)*1e3:.2f} ms/token")
    print("sample tokens:", np.asarray(gen)[0].tolist()[:12])
    return 0


if __name__ == "__main__":
    sys.exit(main())
