"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) case.

MUST set XLA_FLAGS before any jax import (device count locks at first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax             # noqa: E402

from repro import sharding           # noqa: E402
from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo                           # noqa: E402
from repro.launch.mesh import make_production_mesh                          # noqa: E402
from repro.launch.roofline import collective_bytes, roofline_report         # noqa: E402
from repro.launch.specs import arch_rules, build_case                       # noqa: E402

DEFAULT_OUT = "artifacts/dryrun"

ASSIGNED = [a for a in ARCH_IDS if a not in ("mnist_dnn", "lenet5",
                                             "char_lstm")]


OPT_LEVERS = ("attn_bf16", "moe_ep", "first_order", "no_remat", "cache_rep",
              "tp_only", "dp_only", "donate")

# every param logical axis — blanked out by the dp_only lever
_PARAM_AXES = ("embed", "heads", "kv_heads", "ffn", "experts", "vocab",
               "ssm_inner", "lru", "mla_rank")


def run_case(arch: str, shape_name: str, *, multi_pod: bool,
             moe_impl: str = "gather", perfed_step: bool = True,
             collect_hlo_stats: bool = True,
             rule_overrides: Optional[Dict[str, Any]] = None,
             opts: tuple = ()) -> Dict[str, Any]:
    import dataclasses

    from repro.config import FLConfig

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    fl = FLConfig()
    if "attn_bf16" in opts:
        cfg = dataclasses.replace(cfg, attn_cast_f32=False)
    if "no_remat" in opts:
        cfg = dataclasses.replace(cfg, remat=False)
    if "moe_ep" in opts:
        moe_impl = "ep"
    if "first_order" in opts:
        fl = dataclasses.replace(fl, first_order=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = arch_rules(cfg, mesh)
    if "tp_only" in opts:
        # pure tensor parallelism: params replicated over data (no ZeRO-3)
        # — kills the per-step weight all-gathers in decode
        rules = rules.with_overrides(embed=())
    if "dp_only" in opts:
        # pure data parallelism: params fully replicated, batch over BOTH
        # axes — for small models the only collective left is the gradient
        # all-reduce (and per-device compute matches the 2-D layout)
        rules = rules.with_overrides(
            batch=("pod", "data", "model"),
            **{a: () for a in _PARAM_AXES})
    if rule_overrides:
        rules = rules.with_overrides(**rule_overrides)
    cohorts = mesh.shape.get("pod", 0) if (multi_pod and shape.kind == "train") \
        else None

    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "multi_pod" if multi_pod else "single_pod",
                           "status": "ok"}
    try:
        with sharding.use_mesh(mesh, rules):
            case = build_case(cfg, shape, mesh, moe_impl=moe_impl, fl=fl,
                              semi_sync_cohorts=cohorts,
                              perfed_step=perfed_step, rules=rules,
                              cache_policy=("replicate" if "cache_rep" in opts
                                            else "auto"))
            donate = ()
            if "donate" in opts:
                # decode: donate the cache (arg 1); train: donate the state
                donate = (1,) if shape.kind == "decode" else (0,)
            jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                             out_shardings=case.out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*case.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec.update({
            "name": case.name,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
        })
        if collect_hlo_stats:
            hlo_text = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo_text)
            # trip-count-aware analysis (XLA cost_analysis counts each scan
            # body once — see EXPERIMENTS.md §Methodology)
            rec["hlo_tc"] = analyze_hlo(hlo_text)
        n_devices = 1
        for v in mesh.shape.values():
            n_devices *= v
        rec["n_devices"] = n_devices
        rec["roofline"] = roofline_report(rec)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned 10)")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' (4 assigned shapes)")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--moe-impl", default="gather", choices=["gather", "ep"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--opt", action="append", default=[],
                    choices=list(OPT_LEVERS),
                    help="§Perf levers (repeatable): attn_bf16 moe_ep "
                         "first_order no_remat")
    args = ap.parse_args(argv)

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single_pod": [False], "multi_pod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_case(arch, shape_name, multi_pod=mp,
                               moe_impl=args.moe_impl,
                               opts=tuple(args.opt))
                rec["tag"] = args.tag
                results.append(rec)
                status = rec["status"]
                peak_gib = rec.get("memory", {}).get("peak_bytes", 0) / 2 ** 30
                extra = (f"flops={rec.get('flops', 0):.3e} "
                         f"peak={peak_gib:.2f}GiB"
                         if status == "ok" else rec.get("error", ""))
                print(f"[{status:4s}] {arch:22s} {shape_name:12s} "
                      f"{'multi' if mp else 'single':6s} "
                      f"({rec['total_s']:6.1f}s) {extra}", flush=True)
                fname = os.path.join(
                    args.out,
                    f"{args.tag}_{arch}_{shape_name}_"
                    f"{'multi' if mp else 'single'}.json")
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cases lowered+compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
