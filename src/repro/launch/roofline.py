"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × mesh), TPU v5e constants:

  compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
  memory     = HLO_bytes   / (chips × 819e9  B/s HBM)
  collective = coll_bytes  / (chips × 50e9   B/s per ICI link)

``cost_analysis`` reports the per-device SPMD module, so terms below divide
by chips only when the numbers are whole-program (we detect via a flag).
Collective bytes are not in cost_analysis — we parse the compiled HLO and sum
operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}<>/ ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes per collective kind from HLO text.

    Uses the *result* shape of each collective op (the data volume the
    collective moves per device, up to the algorithm factor)."""
    out: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"^[%\w.\-]+\s*=\s*(.+?)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": out, "count_by_kind": count,
            "total_bytes": sum(out.values())}


def roofline_report(rec: Dict[str, Any], *, per_device: bool = True
                    ) -> Dict[str, Any]:
    """Compute the three roofline terms for one dry-run record.

    All HLO numbers are from the per-device SPMD module.  When the
    trip-count-aware analysis (``hlo_tc``) is present it is preferred: XLA's
    ``cost_analysis`` counts each while/scan body ONCE, so for
    scan-over-layers models the raw numbers understate true per-step work by
    ~num_layers× (see EXPERIMENTS.md §Methodology).
    """
    chips = rec.get("n_devices", 1)
    tc = rec.get("hlo_tc") or {}
    flops = tc.get("dot_flops_tc") or rec.get("flops", 0.0)
    # HBM traffic: XLA's post-fusion "bytes accessed" is the best per-body
    # estimate but counts scan bodies once; scale it by the trip-count flop
    # ratio (scan bodies dominate both flops and bytes in layer stacks).
    # ``bytes_estimate_tc`` (pre-fusion Σ result bytes) is only an upper
    # bound and NOT used for the term.
    raw_bytes = rec.get("bytes_accessed", 0.0)
    raw_flops = rec.get("flops", 0.0)
    if tc.get("dot_flops_tc") and raw_flops > 0:
        scale = max(1.0, tc["dot_flops_tc"] / raw_flops)
        bytes_acc = raw_bytes * scale
    else:
        bytes_acc = raw_bytes
    coll = (tc.get("collective_total_tc")
            if tc.get("collective_total_tc") is not None
            else rec.get("collectives", {}).get("total_bytes", 0.0))
    div = 1.0 if per_device else float(chips)
    t_compute = flops / div / PEAK_FLOPS
    t_memory = bytes_acc / div / HBM_BW
    t_coll = coll / div / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant,
            "bound_fraction": terms[dominant] / max(sum(terms.values()), 1e-30)}


def model_flops(arch_params: float, tokens: float, *, moe_active: float = 0.0
                ) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE)."""
    n = moe_active if moe_active > 0 else arch_params
    return 6.0 * n * tokens
