from repro.wireless.channel import EdgeNetwork, sample_channels
from repro.wireless.timing import compute_time, upload_time, round_time
