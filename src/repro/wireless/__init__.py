from repro.wireless.channel import EdgeNetwork, sample_channels
from repro.wireless.timing import compute_time, round_time, upload_time

__all__ = [
    "EdgeNetwork",
    "compute_time",
    "round_time",
    "sample_channels",
    "upload_time",
]
