"""Latency model — Eq. (10)–(12).

* ``compute_time``  Tcmp = c_i·d_i / ϑ_i         (Eq. 11)
* ``upload_time``   Tcom = Z / r_k^i             (Eq. 10), Z in bits
* ``round_time``    T_k  = max over scheduled UEs (C1.1)

``compute_times`` / ``upload_times`` are the vectorized counterparts used by
the unified event-loop driver (``fl/driver.py``) to price a whole requeue of
UEs in one shot.  They apply the exact same sequence of IEEE-754 operations
as the scalar forms, so a batched requeue is *bitwise identical* to the
legacy per-UE loop (pinned by ``tests/test_driver.py``).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.bandwidth import UEChannel, uplink_rate

LN2 = float(np.log(2.0))


def compute_time(cycles_per_sample: float, n_samples: int,
                 cpu_freq_hz: float) -> float:
    return cycles_per_sample * n_samples / cpu_freq_hz


def upload_time(z_bits: float, bandwidth_hz: float, ch: UEChannel) -> float:
    """Z bits over rate r(b) nats/s → seconds (bits × ln2 = nats)."""
    r = float(uplink_rate(bandwidth_hz, ch))
    if r <= 0:
        return float("inf")
    return z_bits * LN2 / r


def compute_times(cycles_per_sample: float, n_samples: np.ndarray,
                  cpu_freq_hz: np.ndarray) -> np.ndarray:
    """Vectorized Eq. (11): ``c·d_i / ϑ_i`` per UE — same op order as
    ``compute_time`` (multiply, then divide), hence bitwise identical."""
    return cycles_per_sample * np.asarray(n_samples) \
        / np.asarray(cpu_freq_hz, dtype=np.float64)


def upload_times(z_bits: float, bandwidth_hz: np.ndarray,
                 q: np.ndarray) -> np.ndarray:
    """Vectorized Eq. (10) over per-UE bandwidths and SNR numerators.

    ``q`` is ``UEChannel.q`` per UE (p·h·d^{−κ}/N₀); the rate expression is
    the same ufunc chain ``b·log1p(q/max(b, ε))`` that ``uplink_rate``
    applies to a scalar, so every lane is bitwise identical to the scalar
    path.  Non-positive rates yield +inf, matching ``upload_time``.
    """
    b = np.asarray(bandwidth_hz, dtype=np.float64)
    r = b * np.log1p(np.asarray(q, dtype=np.float64)
                     / np.maximum(b, 1e-12))
    out = np.full_like(r, np.inf)
    np.divide(z_bits * LN2, r, out=out, where=r > 0)
    return out


def finish_times(z_bits, bandwidths, channels, tcmp) -> np.ndarray:
    """Per-UE compute+upload finish time of a bandwidth allocation — the
    quantity Theorem 2 equalises.  ``z_bits`` may be a scalar (every UE
    uploads the same model) or per-UE; delegates to the one vectorized
    Eq. (10) implementation (``upload_times``), so allocation scoring in
    the Theorem-2 property suite can never drift from the driver's
    pricing."""
    n = len(channels)
    q = np.array([ch.q for ch in channels], dtype=np.float64)
    z = np.broadcast_to(np.asarray(z_bits, dtype=np.float64), (n,))
    return np.asarray(tcmp, dtype=np.float64) \
        + upload_times(z, np.asarray(bandwidths, dtype=np.float64), q)


def round_time(times: np.ndarray) -> float:
    """T_k = max_{i∈A_k} T_k^i.  An empty scheduled set (a hierarchical
    cell with no arrivals this round) takes no time, rather than letting
    ``np.max([])`` raise a bare ValueError."""
    times = np.asarray(times)
    if times.size == 0:
        return 0.0
    return float(np.max(times))


def model_bits(params, bits_per_param: int = 32) -> float:
    """Z — payload size for one gradient upload (16 = fp16 uploads)."""
    if bits_per_param <= 0:
        raise ValueError(f"bits_per_param must be positive, "
                         f"got {bits_per_param}")
    return float(sum(x.size for x in jax.tree.leaves(params))) * bits_per_param
