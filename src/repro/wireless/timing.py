"""Latency model — Eq. (10)–(12).

* ``compute_time``  Tcmp = c_i·d_i / ϑ_i         (Eq. 11)
* ``upload_time``   Tcom = Z / r_k^i             (Eq. 10), Z in bits
* ``round_time``    T_k  = max over scheduled UEs (C1.1)
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.bandwidth import UEChannel, uplink_rate

LN2 = float(np.log(2.0))


def compute_time(cycles_per_sample: float, n_samples: int,
                 cpu_freq_hz: float) -> float:
    return cycles_per_sample * n_samples / cpu_freq_hz


def upload_time(z_bits: float, bandwidth_hz: float, ch: UEChannel) -> float:
    """Z bits over rate r(b) nats/s → seconds (bits × ln2 = nats)."""
    r = float(uplink_rate(bandwidth_hz, ch))
    if r <= 0:
        return float("inf")
    return z_bits * LN2 / r


def round_time(times: np.ndarray) -> float:
    """T_k = max_{i∈A_k} T_k^i.  An empty scheduled set (a hierarchical
    cell with no arrivals this round) takes no time, rather than letting
    ``np.max([])`` raise a bare ValueError."""
    times = np.asarray(times)
    if times.size == 0:
        return 0.0
    return float(np.max(times))


def model_bits(params, bits_per_param: int = 32) -> float:
    """Z — payload size for one gradient upload (16 = fp16 uploads)."""
    if bits_per_param <= 0:
        raise ValueError(f"bits_per_param must be positive, "
                         f"got {bits_per_param}")
    return float(sum(x.size for x in jax.tree.leaves(params))) * bits_per_param
