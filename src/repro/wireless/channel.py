"""Mobile-edge wireless model — Sec. III-A (Eq. 9) + Table I parameters.

UEs are dropped uniformly in a cell of radius R around the BS; uplink rates
follow OFDMA with per-UE bandwidth b:  r = b·ln(1 + p·h·d^{−κ} / (b·N₀)),
with Rayleigh small-scale fading h resampled per communication round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.config import WirelessConfig
from repro.core.bandwidth import UEChannel


def noise_w_per_hz(n0_dbm_per_hz: float) -> float:
    return 10.0 ** (n0_dbm_per_hz / 10.0) / 1000.0


_noise_w_per_hz = noise_w_per_hz      # historical private alias


def pathloss_pow(distances: np.ndarray, kappa: float) -> np.ndarray:
    """``d^{−κ}`` per UE, computed with *python-scalar* pow.

    ``UEChannel.q`` evaluates ``dist ** (-kappa)`` on python floats; numpy's
    vectorized pow differs from libm's scalar pow by 1 ulp on a few percent
    of inputs, which would break the bitwise-reproduction pins on the event
    loop.  Distances only change when mobility re-associates, so the driver
    caches this per distances-array and the scalar loop stays off the
    per-requeue hot path.
    """
    return np.array([float(x) ** (-kappa) for x in np.asarray(distances)],
                    dtype=np.float64)


def make_channel(cfg: WirelessConfig, dist: float, h: float) -> UEChannel:
    """One UE's channel snapshot from config + geometry + fading — the one
    place Table-I parameters turn into a ``UEChannel`` (shared by the
    static ``EdgeNetwork`` and the mobile ``MultiCellNetwork``)."""
    return UEChannel(p=cfg.tx_power_w, h=float(h), dist=float(dist),
                     kappa=cfg.path_loss_exp,
                     n0=_noise_w_per_hz(cfg.noise_dbm_per_hz))


def mean_rates_for(cfg: WirelessConfig, distances: np.ndarray,
                   bandwidth_per_ue: Optional[float] = None) -> np.ndarray:
    """Expected uplink rate per UE at mean fading and equal-split bandwidth
    (the Sec. VI-A-4 η-derivation input)."""
    from repro.core.bandwidth import uplink_rate
    n = len(distances)
    b = bandwidth_per_ue or cfg.total_bandwidth_hz / n
    h_mean = cfg.rayleigh_scale * np.sqrt(np.pi / 2.0)
    return np.array([
        float(uplink_rate(b, make_channel(cfg, distances[i], h_mean)))
        for i in range(n)])


@dataclass
class EdgeNetwork:
    """A drop of n UEs in the cell: static geometry + per-UE compute speeds."""
    cfg: WirelessConfig
    n_ues: int
    distances: np.ndarray          # [n] m
    cpu_freq: np.ndarray           # [n] Hz — heterogeneous CPUs (stragglers!)
    rng: np.random.Generator

    @classmethod
    def drop(cls, cfg: WirelessConfig, n_ues: int, seed: int = 0,
             uniform_distance: bool = False) -> "EdgeNetwork":
        rng = np.random.default_rng(seed)
        if uniform_distance:
            distances = np.full(n_ues, cfg.cell_radius_m / 2.0)
        else:
            # uniform in the disc → sqrt for radius; min 5 m
            distances = np.maximum(
                cfg.cell_radius_m * np.sqrt(rng.uniform(size=n_ues)), 5.0)
        # CPU frequencies log-uniform over the heterogeneity ratio
        ratio = max(cfg.cpu_hetero, 1.0)
        cpu = cfg.cpu_freq_hz * np.exp(
            rng.uniform(np.log(1.0 / ratio), 0.0, size=n_ues))
        return cls(cfg=cfg, n_ues=n_ues, distances=distances, cpu_freq=cpu,
                   rng=rng)

    # ------------------------------------------------------------------
    def sample_fading(self) -> np.ndarray:
        """Rayleigh small-scale coefficients h_k^i for one round (Table I:
        scale parameter 40)."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=self.n_ues)

    def sample_fading_batch(self, k: int) -> np.ndarray:
        """``k`` successive ``sample_fading()`` draws as ONE ``[k, n]`` RNG
        call — bitwise identical to the loop (numpy Generators fill arrays
        from the bitstream in C order), at a fraction of the call overhead.
        The unified driver prices a whole requeue per draw this way."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=(k, self.n_ues))

    def channel(self, ue: int, h: Optional[float] = None) -> UEChannel:
        hval = float(h) if h is not None else float(self.sample_fading()[ue])
        return make_channel(self.cfg, self.distances[ue], hval)

    def channels(self, h: Optional[np.ndarray] = None) -> list:
        h = h if h is not None else self.sample_fading()
        return [self.channel(i, h[i]) for i in range(self.n_ues)]

    def mean_rates(self, bandwidth_per_ue: Optional[float] = None
                   ) -> np.ndarray:
        """Expected uplink rate per UE at equal-split bandwidth (used to
        derive distance-based η in Sec. VI-A-4)."""
        return mean_rates_for(self.cfg, self.distances, bandwidth_per_ue)


def sample_channels(cfg: WirelessConfig, n_ues: int, seed: int = 0):
    return EdgeNetwork.drop(cfg, n_ues, seed)
