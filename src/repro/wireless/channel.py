"""Mobile-edge wireless model — Sec. III-A (Eq. 9) + Table I parameters.

UEs are dropped uniformly in a cell of radius R around the BS; uplink rates
follow OFDMA with per-UE bandwidth b:  r = b·ln(1 + p·h·d^{−κ} / (b·N₀)),
with Rayleigh small-scale fading h resampled per communication round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.config import WirelessConfig
from repro.core.bandwidth import UEChannel


def noise_w_per_hz(n0_dbm_per_hz: float) -> float:
    return 10.0 ** (n0_dbm_per_hz / 10.0) / 1000.0


_noise_w_per_hz = noise_w_per_hz      # historical private alias


# ---------------------------------------------------------------------------
# counter-based fading (``WirelessConfig.rng == "counter"``)
# ---------------------------------------------------------------------------
# The legacy stream prices a requeue of k UEs by drawing the full [k, n]
# Rayleigh matrix (to stay bitwise identical to the original per-UE loop,
# which drew the whole [n] vector per cycle) — O(k·n) host RNG work that
# dominates warm wall at 16k+ UEs.  The counter stream instead derives each
# lane's coefficient from (seed, ue, per-UE draw counter) with a splitmix64
# hash and the inverse Rayleigh CDF: O(k) per requeue, and the value a UE's
# j-th cycle sees is a pure function of (seed, ue, j) — independent of how
# the event loop batches its pricing calls.

_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_MIX2 = np.uint64(0x94D049BB133111EB)
_FADE_STREAM = np.uint64(0x66616465)          # "fade" — stream separation
_U53 = 2.0 ** -53


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 in, uint64 out)."""
    z = np.asarray(x, dtype=np.uint64) + _SM_GAMMA
    z = (z ^ (z >> np.uint64(30))) * _SM_MIX1
    z = (z ^ (z >> np.uint64(27))) * _SM_MIX2
    return z ^ (z >> np.uint64(31))


def counter_fading_seed(seed: int) -> np.uint64:
    """Per-network base key of the counter fading stream."""
    # hash through a length-1 array: numpy warns on *scalar* uint64
    # wraparound but wraps array lanes silently (wrapping is the point)
    s = np.asarray([np.int64(seed) & np.int64(0x7FFFFFFFFFFFFFFF)],
                   dtype=np.uint64)
    return splitmix64(s ^ _FADE_STREAM)[0]


def counter_rayleigh(base: np.uint64, ues: np.ndarray, counters: np.ndarray,
                     scale: float) -> np.ndarray:
    """Rayleigh(scale) draw for each (ue, counter) lane.

    Two chained splitmix64 rounds hash (base, ue, counter) to a uniform in
    [0, 1), which the inverse CDF h = σ·√(−2·ln(1 − u)) maps to Rayleigh —
    same marginal distribution as ``numpy.Generator.rayleigh``, different
    bitstream (moment/KS properties pinned in ``tests/test_counter_rng.py``).
    """
    ues = np.asarray(ues, dtype=np.uint64)
    counters = np.asarray(counters, dtype=np.uint64)
    z = splitmix64(np.asarray(base, np.uint64) ^ (ues * _SM_MIX1))
    z = splitmix64(z ^ counters)
    u = (z >> np.uint64(11)).astype(np.float64) * _U53
    return scale * np.sqrt(-2.0 * np.log1p(-u))


class CounterFadingMixin:
    """Counter-stream pricing shared by ``EdgeNetwork`` and
    ``MultiCellNetwork``.  Hosts the per-UE draw counters; ``fading_lanes``
    is the O(k) hot-path entry the driver uses when ``cfg.rng ==
    "counter"``."""

    def _init_counter_fading(self, seed: int, n_ues: int) -> None:
        self._fade_base = counter_fading_seed(seed)
        self._fade_count = np.zeros(n_ues, dtype=np.uint64)

    def fading_lanes(self, idx: np.ndarray) -> np.ndarray:
        """One Rayleigh coefficient per requeued lane, consuming each
        lane's private counter — O(k log k), no [k, n] matrix.

        A UE repeated within one call consumes successive counters, so the
        stream a UE sees depends only on its own draw count, never on how
        the event loop batches pricing calls (the driver never repeats a
        UE within a drain, but the contract holds regardless)."""
        idx = np.asarray(idx, dtype=np.int64)
        k = len(idx)
        if k == 0:
            return np.zeros(0)
        order = np.argsort(idx, kind="stable")
        s = idx[order]
        first = np.empty(k, dtype=bool)
        first[0] = True
        np.not_equal(s[1:], s[:-1], out=first[1:])
        starts = np.nonzero(first)[0]
        counts = np.diff(np.append(starts, k))
        # occurrence rank within each UE's run of the (stable-)sorted lanes
        rank = (np.arange(k) - np.repeat(starts, counts)).astype(np.uint64)
        ctr = self._fade_count[s] + rank
        self._fade_count[s[starts]] += counts.astype(np.uint64)
        out = np.empty(k, dtype=np.float64)
        out[order] = counter_rayleigh(self._fade_base, s, ctr,
                                      self.cfg.rayleigh_scale)
        return out


def validate_rng_mode(rng: str) -> str:
    if rng not in ("legacy", "counter"):
        raise ValueError(f"unknown fading rng mode {rng!r}; "
                         f"known: ['counter', 'legacy']")
    return rng


def pathloss_pow(distances: np.ndarray, kappa: float) -> np.ndarray:
    """``d^{−κ}`` per UE, computed with *python-scalar* pow.

    ``UEChannel.q`` evaluates ``dist ** (-kappa)`` on python floats; numpy's
    vectorized pow differs from libm's scalar pow by 1 ulp on a few percent
    of inputs, which would break the bitwise-reproduction pins on the event
    loop.  Distances only change when mobility re-associates, so the driver
    caches this per distances-array and the scalar loop stays off the
    per-requeue hot path.
    """
    return np.array([float(x) ** (-kappa) for x in np.asarray(distances)],
                    dtype=np.float64)


def make_channel(cfg: WirelessConfig, dist: float, h: float) -> UEChannel:
    """One UE's channel snapshot from config + geometry + fading — the one
    place Table-I parameters turn into a ``UEChannel`` (shared by the
    static ``EdgeNetwork`` and the mobile ``MultiCellNetwork``)."""
    return UEChannel(p=cfg.tx_power_w, h=float(h), dist=float(dist),
                     kappa=cfg.path_loss_exp,
                     n0=_noise_w_per_hz(cfg.noise_dbm_per_hz))


def mean_rates_for(cfg: WirelessConfig, distances: np.ndarray,
                   bandwidth_per_ue: Optional[float] = None) -> np.ndarray:
    """Expected uplink rate per UE at mean fading and equal-split bandwidth
    (the Sec. VI-A-4 η-derivation input)."""
    from repro.core.bandwidth import uplink_rate
    n = len(distances)
    b = bandwidth_per_ue or cfg.total_bandwidth_hz / n
    h_mean = cfg.rayleigh_scale * np.sqrt(np.pi / 2.0)
    return np.array([
        float(uplink_rate(b, make_channel(cfg, distances[i], h_mean)))
        for i in range(n)])


@dataclass
class EdgeNetwork(CounterFadingMixin):
    """A drop of n UEs in the cell: static geometry + per-UE compute speeds."""
    cfg: WirelessConfig
    n_ues: int
    distances: np.ndarray          # [n] m
    cpu_freq: np.ndarray           # [n] Hz — heterogeneous CPUs (stragglers!)
    rng: np.random.Generator

    @classmethod
    def drop(cls, cfg: WirelessConfig, n_ues: int, seed: int = 0,
             uniform_distance: bool = False) -> "EdgeNetwork":
        validate_rng_mode(cfg.rng)
        rng = np.random.default_rng(seed)
        if uniform_distance:
            distances = np.full(n_ues, cfg.cell_radius_m / 2.0)
        else:
            # uniform in the disc → sqrt for radius; min 5 m
            distances = np.maximum(
                cfg.cell_radius_m * np.sqrt(rng.uniform(size=n_ues)), 5.0)
        # CPU frequencies log-uniform over the heterogeneity ratio
        ratio = max(cfg.cpu_hetero, 1.0)
        cpu = cfg.cpu_freq_hz * np.exp(
            rng.uniform(np.log(1.0 / ratio), 0.0, size=n_ues))
        net = cls(cfg=cfg, n_ues=n_ues, distances=distances, cpu_freq=cpu,
                  rng=rng)
        net._init_counter_fading(seed, n_ues)
        return net

    # ------------------------------------------------------------------
    def sample_fading(self) -> np.ndarray:
        """Rayleigh small-scale coefficients h_k^i for one round (Table I:
        scale parameter 40)."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=self.n_ues)

    def sample_fading_batch(self, k: int) -> np.ndarray:
        """``k`` successive ``sample_fading()`` draws as ONE ``[k, n]`` RNG
        call — bitwise identical to the loop (numpy Generators fill arrays
        from the bitstream in C order), at a fraction of the call overhead.
        The unified driver prices a whole requeue per draw this way."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=(k, self.n_ues))

    def channel(self, ue: int, h: Optional[float] = None) -> UEChannel:
        hval = float(h) if h is not None else float(self.sample_fading()[ue])
        return make_channel(self.cfg, self.distances[ue], hval)

    def channels(self, h: Optional[np.ndarray] = None) -> list:
        h = h if h is not None else self.sample_fading()
        return [self.channel(i, h[i]) for i in range(self.n_ues)]

    def mean_rates(self, bandwidth_per_ue: Optional[float] = None
                   ) -> np.ndarray:
        """Expected uplink rate per UE at equal-split bandwidth (used to
        derive distance-based η in Sec. VI-A-4)."""
        return mean_rates_for(self.cfg, self.distances, bandwidth_per_ue)


def sample_channels(cfg: WirelessConfig, n_ues: int, seed: int = 0):
    return EdgeNetwork.drop(cfg, n_ues, seed)
