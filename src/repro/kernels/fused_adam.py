"""Fused Adam update kernel (TPU Pallas target, validated interpret=True).

One pass over a flat parameter shard updates (p, m, v) together — three
HBM-read + three HBM-write streams instead of the ~10 an unfused XLA graph
needs.  Scalars (lr, bias corrections) arrive via an SMEM block so the kernel
is reusable across steps without recompilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 4096


def _adam_kernel(scal_ref, p_ref, m_ref, v_ref, g_ref,
                 po_ref, mo_ref, vo_ref, *, b1: float, b2: float, eps: float):
    lr = scal_ref[0]
    bc1 = scal_ref[1]     # 1 - b1^t
    bc2 = scal_ref[2]     # 1 - b2^t
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mh = m / bc1
    vh = v / bc2
    po_ref[...] = (p_ref[...].astype(jnp.float32)
                   - lr * mh / (jnp.sqrt(vh) + eps)).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


def fused_adam_flat(p: jax.Array, m: jax.Array, v: jax.Array, g: jax.Array,
                    *, lr, t, b1: float = 0.9, b2: float = 0.95,
                    eps: float = 1e-8, block: int = BLOCK,
                    interpret: bool = True):
    """Update one flat tensor.  p [N] (any float dtype), m/v [N] f32, g [N].

    Returns (new_p, new_m, new_v)."""
    n = p.shape[0]
    n_pad = -(-n // block) * block
    if n_pad != n:
        def padder(x):
            return jnp.pad(x, (0, n_pad - n))
        p, m, v, g = padder(p), padder(m), padder(v), padder(g)
    tf = jnp.asarray(t, jnp.float32)
    scal = jnp.stack([jnp.asarray(lr, jnp.float32),
                      1.0 - jnp.power(b1, tf),
                      1.0 - jnp.power(b2, tf)])
    grid = (n_pad // block,)
    kernel = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps)
    new_p, new_m, new_v = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # scalars
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), p.dtype),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(scal, p, m.astype(jnp.float32), v.astype(jnp.float32), g)
    return new_p[:n], new_m[:n], new_v[:n]
