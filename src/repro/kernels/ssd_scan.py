"""Mamba-2 SSD chunk kernel (TPU Pallas target, validated interpret=True).

The SSD algorithm splits the sequence into chunks; the quadratic chunk-local
work (decay matrix + scores + per-chunk state summaries) is the compute hot
spot and lives in this kernel.  The O(num_chunks) inter-chunk recurrence and
the rank-1 state broadcast stay in jnp (see ``ops.ssd_chunked``).

Per (batch, chunk) program, VMEM blocks:
  x [Q,H,P], dt [Q,H], b [Q,N], c [Q,N], a [H]  →
  y_intra [Q,H,P], state [H,P,N], chunk_decay [H], in_decay [H,Q]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                y_ref, st_ref, dec_ref, indec_ref):
    x = x_ref[0, 0].astype(jnp.float32)         # [Q,H,P]
    dt = dt_ref[0, 0].astype(jnp.float32)       # [Q,H]
    b = b_ref[0, 0].astype(jnp.float32)         # [Q,N]
    c = c_ref[0, 0].astype(jnp.float32)         # [Q,N]
    a = a_ref[...].astype(jnp.float32)          # [H]

    q = x.shape[0]
    da = (dt * a[None, :]).T                    # [H,Q] (≤ 0)
    cum = jnp.cumsum(da, axis=1)                # [H,Q]

    # decay matrix L[h,i,j] = exp(cum_i − cum_j) for j ≤ i else 0
    diff = cum[:, :, None] - cum[:, None, :]    # [H,Q,Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(tri[None], jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # [Q,Q]
    xdt = x * dt[..., None]                                        # [Q,H,P]
    y = jnp.einsum("ij,hij,jhp->ihp", scores, lmat, xdt)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[:, -1:] - cum)                         # [H,Q]
    st = jnp.einsum("jn,hj,jhp->hpn", b, decay_end, xdt)
    st_ref[0, 0] = st.astype(st_ref.dtype)

    dec_ref[0, 0] = jnp.exp(cum[:, -1]).astype(dec_ref.dtype)
    indec_ref[0, 0] = jnp.exp(cum).astype(indec_ref.dtype)


def ssd_chunk_pallas(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                     c: jax.Array, *, interpret: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Chunk-local SSD terms.

    x [B,NC,Q,H,P], dt [B,NC,Q,H], a [H], b/c [B,NC,Q,N] →
      (y_intra [B,NC,Q,H,P], states [B,NC,H,P,N],
       chunk_decay [B,NC,H], in_decay [B,NC,H,Q])
    """
    bs, nc, qlen, h, p = x.shape
    n = b.shape[-1]
    grid = (bs, nc)

    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qlen, h, p), lambda bi, ci: (bi, ci, 0, 0, 0)),
            pl.BlockSpec((1, 1, qlen, h), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, qlen, n), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, qlen, n), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((h,), lambda bi, ci: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qlen, h, p), lambda bi, ci: (bi, ci, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, p, n), lambda bi, ci: (bi, ci, 0, 0, 0)),
            pl.BlockSpec((1, 1, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, h, qlen), lambda bi, ci: (bi, ci, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, nc, qlen, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h, qlen), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, b, c, a)


# squeeze helper for the [0]-indexed block refs above: BlockSpec blocks carry
# the leading singleton grid dims, so refs are indexed with [0] / [0, 0].
