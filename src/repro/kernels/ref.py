"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """Materialised attention. q [B,Hq,L,D], k/v [B,Hkv,L,D] → [B,Hq,L,D]."""
    b, hq, sl, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sl)[:, None]
    k_pos = jnp.arange(sl)[None, :]
    mask = jnp.ones((sl, sl), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array, q_pos: jax.Array, *,
                         window: int = 0,
                         scale: Optional[float] = None) -> jax.Array:
    """One-token decode attention against a (ring) cache.

    q [B,Hq,D]; k/v [B,Hkv,S,D]; pos [B,S] (−1 = empty); q_pos [B]."""
    b, hq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = (pos >= 0) & (pos <= q_pos[:, None])
    if window > 0:
        mask &= (q_pos[:, None] - pos) < window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_chunk_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                  c: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-chunk SSD reference (naive recurrence).

    x [B,Q,H,P], dt [B,Q,H], a [H], b/c [B,Q,N] →
      (y_intra [B,Q,H,P]  — zero initial state,
       state   [B,H,P,N]  — end-of-chunk state,
       decay   [B,H]      — total chunk decay)
    """
    bs, qlen, h, p = x.shape
    n = b.shape[-1]
    da = dt * a[None, None, :]                      # [B,Q,H]

    def step(carry, t):
        s = carry
        dai = da[:, t]                              # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], b[:, t])
        s = s * jnp.exp(dai)[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", s, c[:, t])
        return s, y

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    s_fin, ys = jax.lax.scan(step, s0, jnp.arange(qlen))
    y = jnp.moveaxis(ys, 0, 1)                      # [B,Q,H,P]
    decay = jnp.exp(da.sum(axis=1))                 # [B,H]
    return y, s_fin, decay


def adam_ref(p, m, v, g, *, lr: float, b1: float, b2: float, eps: float,
             t: int):
    """Single-tensor Adam reference."""
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mh = m2 / (1 - b1 ** t)
    vh = v2 / (1 - b2 ** t)
    return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2


def stale_aggregate_ref(params, buffers, mask, *, beta: float):
    """Eq. (8): w − (β/A)·Σ_c mask_c · buf_c for one tensor.

    params [D...], buffers [C, D...], mask [C]."""
    a = jnp.maximum(mask.sum(), 1.0)
    agg = jnp.einsum("c...,c->...", buffers.astype(jnp.float32), mask)
    return (params.astype(jnp.float32) - (beta / a) * agg).astype(params.dtype)
