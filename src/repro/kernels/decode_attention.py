"""Single-token decode attention kernel (TPU Pallas target, interpret-
validated): one query row against a (ring-buffer) KV cache.

This is the serving hot spot — per generated token the whole cache streams
through VMEM once.  Blockwise online softmax over the cache-sequence axis:

  grid = (batch·q_heads, num_s_blocks)

The ring buffer's validity/window logic uses the cached absolute positions
(pos < 0 = empty slot), identical to the model's ``_attn_scores_mask``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, window: int,
                   num_s_blocks: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # [1, D]
    k = k_ref[0].astype(jnp.float32)                    # [bs, D]
    v = v_ref[0].astype(jnp.float32)                    # [bs, D]
    pos = pos_ref[0]                                    # [bs] int32
    q_pos = qpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale  # [bs]
    mask = (pos >= 0) & (pos <= q_pos)
    if window > 0:
        mask &= (q_pos - pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)        # [bs]
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[0] = alpha * l_ref[0] + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p[:, None] * v).sum(
        axis=0, keepdims=True)
    m_ref[0] = m_cur

    @pl.when(si == num_s_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                          pos: jax.Array, q_pos: jax.Array, *,
                          window: int = 0, scale: Optional[float] = None,
                          block_s: int = DEFAULT_BLOCK_S,
                          interpret: bool = True) -> jax.Array:
    """q [B, Hq, D]; k/v [B, Hkv, S, D]; pos [B, S]; q_pos [B] → [B, Hq, D]."""
    b, hq, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    block_s = min(block_s, s_len)
    s_pad = -(-s_len // block_s) * block_s
    if s_pad != s_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s_len), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, s_pad - s_len)), constant_values=-1)
    ns = s_pad // block_s

    qf = q.reshape(b * hq, 1, d)
    kf = k.reshape(b * hkv, s_pad, d)
    vf = v.reshape(b * hkv, s_pad, d)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               num_s_blocks=ns)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, si: (bh // hq,)),
            pl.BlockSpec((1, 1, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda bh, si: ((bh // hq) * hkv + (bh % hq) // group,
                                         si, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda bh, si: ((bh // hq) * hkv + (bh % hq) // group,
                                         si, 0)),
            pl.BlockSpec((1, block_s), lambda bh, si: (bh // hq, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), qf, kf, vf, pos.astype(jnp.int32))
    return out.reshape(b, hq, d)
