"""Jit'd public wrappers around the Pallas kernels.

Models call these through ``cfg.attn_impl == "pallas"`` etc.; tests compare
each against the pure-jnp oracles in ``ref.py``.  ``interpret=True`` is the
CPU-container default; flip to False on real TPUs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhld
from repro.kernels.fused_adam import fused_adam_flat
from repro.kernels.ssd_scan import ssd_chunk_pallas
from repro.kernels.stale_aggregate import stale_aggregate_flat

# ``ref`` / ``stale_aggregate_flat`` / the tree aggregators below are
# deliberate ops.* passthroughs (historical public entry points).
__all__ = [
    "INTERPRET",
    "flash_attention",
    "fused_adam_tree",
    "masked_aggregate_tree",
    "ref",
    "ssd_chunked",
    "stale_aggregate_flat",
    "stale_aggregate_tree",
]

INTERPRET = True   # CPU container; set False on TPU


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """Model-layout wrapper: q [B,L,H,D], k/v [B,L,Hkv,D] → [B,L,H,D]."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = flash_attention_bhld(qt, kt, vt, causal=causal, window=window,
                               interpret=INTERPRET)
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int, *, interpret: bool = True
                ) -> Tuple[jax.Array, jax.Array]:
    """Pallas-backed drop-in for ``models.ssm.ssd_chunked``.

    x [B,L,H,P], dt [B,L,H], a [H], b/c [B,L,N] → (y [B,L,H,P], final_state).
    """
    bs, sl, h, p = x.shape
    n = b.shape[-1]
    assert sl % chunk == 0
    nc = sl // chunk
    xr = x.reshape(bs, nc, chunk, h, p)
    dtr = dt.reshape(bs, nc, chunk, h)
    br = b.reshape(bs, nc, chunk, n)
    cr = c.reshape(bs, nc, chunk, n)

    y_intra, states, chunk_decay, in_decay = ssd_chunk_pallas(
        xr.astype(jnp.float32), dtr.astype(jnp.float32),
        a.astype(jnp.float32), br.astype(jnp.float32), cr.astype(jnp.float32),
        interpret=interpret)

    # inter-chunk recurrence (linear in num_chunks — stays in jnp)
    def step(s_prev, inp):
        dec, st = inp
        return s_prev * dec[..., None, None] + st, s_prev

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                    # [B,NC,H,P,N]
    y_inter = jnp.einsum("bzin,bzhi,bzhpn->bzihp",
                         cr.astype(jnp.float32), in_decay, s_prevs)
    y = (y_intra + y_inter).reshape(bs, sl, h, p)
    return y.astype(x.dtype), s_final.astype(x.dtype)


def fused_adam_tree(params, m, v, grads, *, lr, t, b1=0.9, b2=0.95, eps=1e-8,
                    interpret: bool = True):
    """Pytree fused-Adam: applies the flat kernel leaf-wise."""
    def upd(p, mi, vi, g):
        shape = p.shape
        np_, nm, nv = fused_adam_flat(
            p.reshape(-1), mi.reshape(-1), vi.reshape(-1),
            g.reshape(-1).astype(jnp.float32), lr=lr, t=t, b1=b1, b2=b2,
            eps=eps, interpret=interpret)
        return np_.reshape(shape), nm.reshape(shape), nv.reshape(shape)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(*args) for args in zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v


# Pytree Eq.-(8) update now lives in kernels/stale_aggregate.py as the
# unified aggregation API (single concat buffer + cached treedef) — this
# re-export keeps the historical ops.* entry point working.
from repro.kernels.stale_aggregate import (  # noqa: E402
    masked_aggregate_tree,
    stale_aggregate_tree,
)
