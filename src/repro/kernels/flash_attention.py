"""Blockwise flash attention (TPU Pallas target, validated interpret=True).

Online-softmax attention with explicit VMEM tiling via BlockSpec:

  grid = (batch, q_heads, num_q_blocks, num_k_blocks)

The k-block axis is innermost ("revisiting" pattern): running max / sum /
accumulator live in VMEM scratch and the output block is finalised on the
last k iteration.  Handles causal masking, sliding windows and GQA (the
kv-head index map is ``h // group``), with padding masked via position iota.

Block sizes default to (128, 128) — MXU-aligned for the TPU target.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, seq_len: int,
                  block_q: int, block_k: int, num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                   # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                   # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len                                # padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_cur[:, None])
    # rows with no valid key yet: keep exp(NEG_INF - NEG_INF) from blowing up
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhld(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         scale: Optional[float] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True) -> jax.Array:
    """q [B, Hq, L, D], k/v [B, Hkv, L, D] → [B, Hq, L, D].

    ``interpret=True`` runs the kernel body in Python on CPU (this container);
    on a real TPU pass ``interpret=False``.
    """
    b, hq, sl, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    l_pad = -(-sl // max(block_q, block_k)) * max(block_q, block_k)
    if l_pad != sl:
        pad = ((0, 0), (0, 0), (0, l_pad - sl), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq = l_pad // block_q
    nk = l_pad // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, seq_len=sl,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, ki: (bi, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, ki: (bi, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, l_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # running max m
            pltpu.VMEM((block_q,), jnp.float32),     # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sl, :]
