"""Masked stale-gradient aggregation kernel — Eq. (8) fused (Pallas TPU
target, validated interpret=True).

    w ← w − (β/A) Σ_c π_c · buf_c

Fusing the masked reduction over the cohort axis with the parameter update
reads each buffer slot exactly once and writes w once — the unfused graph
materialises the Σ intermediate in HBM.  Cohort count is small and static,
so the reduction is an unrolled VMEM loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 4096


def _agg_kernel(scal_ref, mask_ref, p_ref, buf_ref, out_ref, *, n_cohorts: int):
    beta_over_a = scal_ref[0]
    acc = jnp.zeros(p_ref.shape, jnp.float32)
    for c in range(n_cohorts):                     # static unroll (C is small)
        acc = acc + mask_ref[c] * buf_ref[c].astype(jnp.float32)
    out_ref[...] = (p_ref[...].astype(jnp.float32)
                    - beta_over_a * acc).astype(out_ref.dtype)


def stale_aggregate_flat(params: jax.Array, buffers: jax.Array,
                         mask: jax.Array, *, beta: float,
                         block: int = BLOCK, interpret: bool = True
                         ) -> jax.Array:
    """params [N], buffers [C, N], mask [C] → updated params [N]."""
    n = params.shape[0]
    c = buffers.shape[0]
    n_pad = -(-n // block) * block
    if n_pad != n:
        params = jnp.pad(params, (0, n_pad - n))
        buffers = jnp.pad(buffers, ((0, 0), (0, n_pad - n)))
    a = jnp.maximum(mask.sum(), 1.0)
    scal = jnp.stack([jnp.asarray(beta, jnp.float32) / a])
    kernel = functools.partial(_agg_kernel, n_cohorts=c)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # β/A
            pl.BlockSpec(memory_space=pltpu.SMEM),           # mask [C]
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), params.dtype),
        interpret=interpret,
    )(scal, mask.astype(jnp.float32), params, buffers)
    return out[:n]
