"""Masked stale-gradient aggregation — Eq. (8) — behind ONE API.

Flat Pallas kernel (TPU target, validated interpret=True):

    w ← w − (β/A) Σ_c π_c · buf_c

Fusing the masked reduction over the cohort axis with the parameter update
reads each buffer slot exactly once and writes w once — the unfused graph
materialises the Σ intermediate in HBM.  Cohort count is small and static,
so the reduction is an unrolled VMEM loop.

On top of the flat kernel sit the *tree* entry points that all protocol code
(``core/server.py``, ``core/semi_sync.py``, ``fl/engine.py``) now shares
instead of hand-rolling ``tree_map`` reductions:

* ``stale_aggregate_tree``   — fused Eq. (8) update of a parameter pytree
  from C payload pytrees (list or stacked) and a weight mask.
* ``masked_aggregate_tree``  — the masked *mean* alone (for callers that
  clip / feed a server optimizer before applying).

Both flatten through a cached ``utils.tree.TreeFlattener`` (one concat
buffer, treedef derived once per structure) and pick the backend:
``"pallas"`` runs the kernel (interpret=True off-TPU), ``"jnp"`` a pure-JAX
matvec, ``"auto"`` uses Pallas only on a real TPU — interpret mode is a
correctness oracle, not a fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.tree import TreeFlattener

BLOCK = 4096


def _agg_kernel(scal_ref, mask_ref, p_ref, buf_ref, out_ref, *, n_cohorts: int):
    beta_over_a = scal_ref[0]
    acc = jnp.zeros(p_ref.shape, jnp.float32)
    for c in range(n_cohorts):                     # static unroll (C is small)
        acc = acc + mask_ref[c] * buf_ref[c].astype(jnp.float32)
    out_ref[...] = (p_ref[...].astype(jnp.float32)
                    - beta_over_a * acc).astype(out_ref.dtype)


def stale_aggregate_flat(params: jax.Array, buffers: jax.Array,
                         mask: jax.Array, *, beta: float,
                         block: int = BLOCK, interpret: bool = True
                         ) -> jax.Array:
    """params [N], buffers [C, N], mask [C] → updated params [N]."""
    n = params.shape[0]
    c = buffers.shape[0]
    n_pad = -(-n // block) * block
    if n_pad != n:
        params = jnp.pad(params, (0, n_pad - n))
        buffers = jnp.pad(buffers, ((0, 0), (0, n_pad - n)))
    a = jnp.maximum(mask.sum(), 1.0)
    scal = jnp.stack([jnp.asarray(beta, jnp.float32) / a])
    kernel = functools.partial(_agg_kernel, n_cohorts=c)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # β/A
            pl.BlockSpec(memory_space=pltpu.SMEM),           # mask [C]
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), params.dtype),
        interpret=interpret,
    )(scal, mask.astype(jnp.float32), params, buffers)
    return out[:n]


# ---------------------------------------------------------------------------
# Tree-level unified API
# ---------------------------------------------------------------------------

def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown aggregation backend {backend!r}")
    return backend


def _stack_payloads(payloads, flat: TreeFlattener) -> jax.Array:
    """List of payload pytrees OR stacked tree (leading C axis) → [C, N]."""
    if isinstance(payloads, (list, tuple)):
        return jnp.stack([flat.flatten(p) for p in payloads])
    return flat.flatten_stacked(payloads)


def stale_aggregate_update(p_flat: jax.Array, buf: jax.Array,
                           mask: jax.Array, *, beta,
                           backend: str = "auto") -> jax.Array:
    """Flat-buffer Eq. (8):  p − (β/A) Σ_c mask_c·buf_c,  A = max(Σ mask, 1).

    The one entry point every aggregation caller funnels through — the
    Pallas kernel on real TPUs, a pure-JAX matvec elsewhere.  Jit-traceable
    (the engine's fused round function calls it on tracers).
    """
    backend = _resolve_backend(backend)
    mask = mask.astype(jnp.float32)
    if backend == "pallas":
        return stale_aggregate_flat(p_flat, buf, mask, beta=beta,
                                    interpret=jax.default_backend() != "tpu")
    a = jnp.maximum(mask.sum(), 1.0)
    return p_flat - (jnp.asarray(beta, jnp.float32) / a) * (mask @ buf)


def _stack_leafwise(payloads):
    """List of payload pytrees → one pytree with a leading cohort axis."""
    if isinstance(payloads, (list, tuple)):
        return jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *payloads)
    return payloads


def masked_aggregate_tree(payloads, mask: jax.Array):
    """Σ_c mask_c · payload_c / max(Σ mask, 1) as an f32 pytree.

    ``payloads`` is a list of pytrees or one pytree with a leading cohort
    axis.  Leaf-wise reduction (XLA fuses it; no concat buffer needed for a
    masked mean).
    """
    stacked = _stack_leafwise(payloads)
    mask = mask.astype(jnp.float32)
    a = jnp.maximum(mask.sum(), 1.0)
    return jax.tree.map(
        lambda bl: jnp.tensordot(mask, bl.astype(jnp.float32), axes=1) / a,
        stacked)


def stale_aggregate_tree(params, payloads, mask: jax.Array, *, beta: float,
                         backend: str = "auto") -> object:
    """Fused Eq. (8) on pytrees:  w ← w − (β/A) Σ_c mask_c · payload_c,
    A = max(Σ mask, 1).  Returns a tree shaped/typed like ``params``.

    A staleness-discounted update (server ``staleness_discount`` < 1) is the
    same call with ``mask_c = λ^{τ_c} · A / Σ λ^{τ}`` — the weights fold
    into the mask, so sync/semi/async and SAFA-style variants all hit this
    one code path.

    The Pallas backend flattens through the cached ``TreeFlattener`` into
    the single concat buffer the kernel wants; the pure-JAX backend reduces
    leaf-wise (bench: ~1.5× faster than materialising the [C, N] concat on
    CPU — XLA fuses the per-leaf masked sums into the update).
    """
    backend = _resolve_backend(backend)
    mask = mask.astype(jnp.float32)
    if backend == "pallas":
        flat = TreeFlattener.for_tree(params)
        p = flat.flatten(params)
        buf = _stack_payloads(payloads, flat)
        out = stale_aggregate_update(p, buf, mask, beta=beta,
                                     backend=backend)
        return flat.unflatten(out)
    stacked = _stack_leafwise(payloads)
    a = jnp.maximum(mask.sum(), 1.0)
    scale = jnp.asarray(beta, jnp.float32) / a

    def upd(pl, bl):
        agg = jnp.tensordot(mask, bl.astype(jnp.float32), axes=1)
        return (pl.astype(jnp.float32) - scale * agg).astype(
            jnp.asarray(pl).dtype)

    return jax.tree.map(upd, params, stacked)
