"""Pallas TPU kernels for the compute hot spots of the model zoo + the
PerFedS² aggregation inner loop.

  flash_attention   blockwise online-softmax attention (causal/SWA/GQA)
  decode_attention  single-token query vs (ring) KV cache — serving hot spot
  ssd_scan          Mamba-2 SSD chunk-local terms
  fused_adam        fused optimizer update (p, m, v in one pass)
  stale_aggregate   Eq. (8) masked stale-gradient aggregation

``ops.py`` exposes jit'd wrappers; ``ref.py`` holds the pure-jnp oracles
every kernel is tested against (interpret=True on this CPU container;
set ``ops.INTERPRET = False`` on real TPUs).
"""
