"""The paper's own experiment models (Section VI-A):

* ``mnist_dnn``  — 2-layer DNN, hidden 100 (MNIST)
* ``lenet5``     — 2 conv + 3 FC (CIFAR-100)
* ``char_lstm``  — LSTM next-character classifier (Shakespeare)

These are the models actually trained by the FL simulator on CPU; they share
the same (init/loss/predict) protocol as the large LM families.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, Any]


def _dense(key, n_in, n_out):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (n_in, n_out)) * math.sqrt(2.0 / n_in)
    return {"dense_w": w, "dense_b": jnp.zeros((n_out,))}


def _apply_dense(p, x):
    return x @ p["dense_w"] + p["dense_b"]


def _xent(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


class MnistDNN:
    """784 → 100 → num_classes (paper: hidden layer of size 100)."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg
        self.n_in = 784
        self.hidden = cfg.d_model or 100
        self.n_cls = cfg.vocab_size or 10

    def init(self, rng) -> Params:
        k1, k2 = jax.random.split(rng)
        return {"fc1": _dense(k1, self.n_in, self.hidden),
                "fc2": _dense(k2, self.hidden, self.n_cls)}

    def predict(self, params, batch):
        x = batch["x"].reshape(batch["x"].shape[0], -1)
        h = jax.nn.relu(_apply_dense(params["fc1"], x))
        return _apply_dense(params["fc2"], h)

    def loss(self, params, batch, rng=None):
        logits = self.predict(params, batch)
        ce = _xent(logits, batch["y"])
        return ce, {"ce": ce,
                    "acc": jnp.mean((jnp.argmax(logits, -1) == batch["y"]))}


class LeNet5:
    """LeNet-5: two conv layers + three FC layers (paper's CIFAR-100 model)."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg
        self.n_cls = cfg.vocab_size or 100
        self.in_ch = 3
        self.in_hw = 32

    def init(self, rng) -> Params:
        ks = jax.random.split(rng, 5)
        def conv(k, h, w, cin, cout):
            return {"conv_w": jax.random.normal(k, (h, w, cin, cout))
                    * math.sqrt(2.0 / (h * w * cin)),
                    "conv_b": jnp.zeros((cout,))}
        flat = 5 * 5 * 16
        return {
            "c1": conv(ks[0], 5, 5, self.in_ch, 6),
            "c2": conv(ks[1], 5, 5, 6, 16),
            "f1": _dense(ks[2], flat, 120),
            "f2": _dense(ks[3], 120, 84),
            "f3": _dense(ks[4], 84, self.n_cls),
        }

    @staticmethod
    def _conv(p, x):
        y = jax.lax.conv_general_dilated(
            x, p["conv_w"], window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + p["conv_b"]

    @staticmethod
    def _pool(x):
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    def predict(self, params, batch):
        x = batch["x"]
        if x.ndim == 3:
            x = x[..., None]
        h = self._pool(jax.nn.relu(self._conv(params["c1"], x)))
        h = self._pool(jax.nn.relu(self._conv(params["c2"], h)))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(_apply_dense(params["f1"], h))
        h = jax.nn.relu(_apply_dense(params["f2"], h))
        return _apply_dense(params["f3"], h)

    def loss(self, params, batch, rng=None):
        logits = self.predict(params, batch)
        ce = _xent(logits, batch["y"])
        return ce, {"ce": ce,
                    "acc": jnp.mean((jnp.argmax(logits, -1) == batch["y"]))}


class CharLSTM:
    """LSTM next-character classifier (paper's Shakespeare model)."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg
        self.vocab = cfg.vocab_size or 80
        self.hidden = cfg.d_model or 256
        self.embed_dim = 8

    def init(self, rng) -> Params:
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        h, e = self.hidden, self.embed_dim
        return {
            "embed": jax.random.normal(k1, (self.vocab, e)) * 0.1,
            "lstm_wx": jax.random.normal(k2, (e, 4 * h)) / math.sqrt(e),
            "lstm_wh": jax.random.normal(k3, (h, 4 * h)) / math.sqrt(h),
            "lstm_b": jnp.zeros((4 * h,)),
            "out": _dense(k4, h, self.vocab),
        }

    def _run(self, params, tokens):
        b, _ = tokens.shape
        x = params["embed"][tokens]                                  # [B,L,E]
        h0 = jnp.zeros((b, self.hidden))
        c0 = jnp.zeros((b, self.hidden))

        def step(carry, xt):
            h, c = carry
            z = xt @ params["lstm_wx"] + h @ params["lstm_wh"] + params["lstm_b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        (_, _), hs = jax.lax.scan(step, (h0, c0), jnp.moveaxis(x, 1, 0))
        return jnp.moveaxis(hs, 0, 1)                                # [B,L,H]

    def predict(self, params, batch):
        hs = self._run(params, batch["tokens"])
        return _apply_dense(params["out"], hs[:, -1, :])             # next char

    def loss(self, params, batch, rng=None):
        """Next-character prediction over the whole sequence."""
        hs = self._run(params, batch["tokens"])
        logits = _apply_dense(params["out"], hs)                     # [B,L,V]
        targets = batch["targets"]
        ce = _xent(logits.reshape(-1, self.vocab), targets.reshape(-1))
        return ce, {"ce": ce}
