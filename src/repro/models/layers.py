"""Shared neural-net layers (pure-functional, pytree params).

Conventions
-----------
* Params are nested dicts with descriptive key names; ``repro.sharding``
  resolves PartitionSpecs from those names (see ``_PARAM_RULES``).
* Activations flow in ``cfg.dtype`` (bf16 by default); softmax/norm statistics
  accumulate in f32.
* Decode caches are dicts of arrays with static shapes.  Sliding-window caches
  are ring buffers storing absolute positions, so the same attention code
  handles full, windowed and ring-buffer caches uniformly.
"""
from __future__ import annotations

import inspect as _inspect
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.config import ModelConfig

# jax ≥ 0.5 exposes jax.shard_map; 0.4.x has it under jax.experimental.
# The replication-check kwarg was renamed check_rep → check_vma, not in
# lockstep with the move, so probe the signature rather than the version.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map
_SHARD_MAP_KW = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return w.astype(dtype)


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias_ln": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias_ln"].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layernorm_init, layernorm
    return rmsnorm_init, rmsnorm


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., L, H, D] (D even), positions: broadcastable to [..., L]."""
    d = x.shape[-1]
    assert d % 2 == 0, "rope head_dim must be even"
    freqs = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * math.log(theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs          # [..., L, D/2]
    cos = jnp.cos(ang)[..., None, :]                                # [..., L, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core (shared by GQA / MLA / cross / local)
# ---------------------------------------------------------------------------

def _attn_scores_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                      window: int) -> jax.Array:
    """Boolean mask [.., Lq, Lk]; k_pos < 0 marks invalid (ring-buffer hole)."""
    valid = k_pos >= 0
    m = valid[..., None, :]
    if causal:
        m = m & (k_pos[..., None, :] <= q_pos[..., :, None])
    if window:
        m = m & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return m


SDPA_CHUNK = 1024   # q-chunk length for the memory-efficient path


def _sdpa_block(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                scale: float, cast_f32: bool = True) -> jax.Array:
    """One q-block of attention. q [B,Lq,Hq,D], k/v [B,Lk,Hkv,Dk/Dv],
    mask [B,Lq,Lk].

    ``cast_f32=False`` keeps k/v in their storage dtype and requests f32
    accumulation from the MXU (``preferred_element_type``) instead of
    materialising an f32 copy of the whole cache — §Perf memory lever.
    """
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, lq, hkv, g, d)
    if cast_f32:
        qg, k, v = (x.astype(jnp.float32) for x in (qg, k, v))
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, lq, hq, v.shape[-1]).astype(q.dtype)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int,
         scale: Optional[float] = None, chunk: int = SDPA_CHUNK,
         cast_f32: bool = True) -> jax.Array:
    """Scaled dot-product attention with GQA head-group broadcast.

    Memory-efficient: when Lq > ``chunk`` the query axis is processed in
    chunks via ``lax.map`` so the [Lq, Lk] score matrix is never fully
    materialised (required for the 32k-prefill shapes).

    q: [B, Lq, Hq, D], k/v: [B, Lk, Hkv, D].
    q_pos [B, Lq], k_pos [B, Lk] — absolute positions; k_pos < 0 = invalid.
    """
    b, lq, hq, d = q.shape
    assert hq % k.shape[2] == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    if lq <= chunk:
        mask = _attn_scores_mask(q_pos, k_pos, causal=causal, window=window)
        return _sdpa_block(q, k, v, mask, scale, cast_f32)

    n_chunks = -(-lq // chunk)
    pad = n_chunks * chunk - lq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qs = q.reshape(b, n_chunks, chunk, hq, d).swapaxes(0, 1)
    qp = q_pos.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def one(args):
        qc, qpc = args
        mask = _attn_scores_mask(qpc, k_pos, causal=causal, window=window)
        mask &= (qpc >= 0)[..., :, None]
        return _sdpa_block(qc, k, v, mask, scale, cast_f32)

    out = jax.lax.map(one, (qs, qp))
    out = out.swapaxes(0, 1).reshape(b, n_chunks * chunk, hq, v.shape[-1])
    return out[:, :lq]


def attention_init(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    dt = _dt(cfg)
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "w_q": dense_init(kq, cfg.d_model, cfg.num_heads * hd, dt),
        "w_k": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dt),
        "w_v": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dt),
        "w_o": dense_init(ko, cfg.num_heads * hd, cfg.d_model, dt,
                          scale=1.0 / math.sqrt(
                              cfg.num_heads * hd * 2 * cfg.num_layers)),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  num_layers: Optional[int] = None, *, stacked: bool = True) -> Params:
    """Ring-buffer KV cache. ``pos`` holds absolute positions (-1 = empty)."""
    dt = _dt(cfg)
    hd = cfg.resolved_head_dim
    nl = num_layers if num_layers is not None else cfg.num_layers
    lead = (nl,) if stacked else ()
    return {
        "k": jnp.zeros(lead + (batch, cache_len, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros(lead + (batch, cache_len, cfg.num_kv_heads, hd), dt),
        "pos": -jnp.ones(lead + (batch, cache_len), jnp.int32),
    }


def attention_apply(params: Params, x: jax.Array, *, cfg: ModelConfig,
                    positions: jax.Array,
                    cache: Optional[Params] = None,
                    kv_input: Optional[jax.Array] = None,
                    causal: bool = True,
                    window: int = 0) -> Tuple[jax.Array, Optional[Params]]:
    """Unified attention.

    * train/prefill: ``cache is None`` or to-be-filled; ``x`` is [B, L, d].
    * decode:        ``cache`` holds past K/V; ``x`` is [B, 1, d].
    * cross:         ``kv_input`` supplies K/V source (no causal mask).

    Returns (out [B, L, d], updated cache or None).
    """
    b, lq, _ = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads

    q = (x @ params["w_q"]).reshape(b, lq, hq, hd)
    src = kv_input if kv_input is not None else x
    lk_new = src.shape[1]
    k = (src @ params["w_k"]).reshape(b, lk_new, hkv, hd)
    v = (src @ params["w_v"]).reshape(b, lk_new, hkv, hd)

    if kv_input is None and cfg.attention != "none":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if cache is None else positions, cfg.rope_theta)
    q = sharding.constrain(q, "batch", None, "act_heads", None)

    new_cache = None
    if cache is not None:
        # write new k/v into the ring buffer at slot = pos % W; when prefilling
        # more than W tokens, only the last W writes are kept (drop the rest so
        # duplicate slots never race).
        w = cache["k"].shape[1]
        pos_b = jnp.broadcast_to(positions, (lq,)).astype(jnp.int32)
        keep = pos_b >= (pos_b[-1] - w + 1)
        slots = jnp.where(keep, pos_b % w, w)                       # w = OOB → dropped
        slots = jnp.broadcast_to(slots, (b, lq))
        bidx = jnp.arange(b)[:, None]
        ck = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype), mode="drop")
        cv = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype), mode="drop")
        cpos = cache["pos"].at[bidx, slots].set(
            jnp.broadcast_to(pos_b, (b, lq)), mode="drop")
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        q_pos = jnp.broadcast_to(positions, (b, lq))
        if lq == 1:
            # decode: attend against the cache contents
            k, v, k_pos = ck, cv, cpos
        else:
            # prefill: attend within the fresh sequence (the ring buffer may
            # only retain the last W entries; outputs need the full window
            # relative to each query position)
            k_pos = q_pos
    else:
        q_pos = jnp.broadcast_to(positions, (b, lq))
        if kv_input is not None:
            k_pos = jnp.zeros((b, lk_new), jnp.int32)               # dense cross
            causal, window = False, 0
        else:
            k_pos = q_pos

    if cfg.attn_impl == "pallas" and cache is None and kv_input is None:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = sdpa(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                   window=window, cast_f32=cfg.attn_cast_f32)
    out = out.reshape(b, lq, hq * hd) @ params["w_o"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Params:
    m = cfg.mla
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    h = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    p: Params = {
        "w_dkv": dense_init(ks[0], cfg.d_model, m.kv_lora_rank, dt),
        "w_kr":  dense_init(ks[1], cfg.d_model, m.qk_rope_head_dim, dt),
        "w_uk":  dense_init(ks[2], m.kv_lora_rank, h * m.qk_nope_head_dim, dt),
        "w_uv":  dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim, dt),
        "w_o":   dense_init(ks[4], h * m.v_head_dim, cfg.d_model, dt,
                            scale=1.0 / math.sqrt(
                                h * m.v_head_dim * 2 * cfg.num_layers)),
        "norm_ckv": rmsnorm_init(m.kv_lora_rank, dt),
    }
    if m.q_lora_rank:
        kq1, kq2 = jax.random.split(ks[5])
        p["w_dq"] = dense_init(kq1, cfg.d_model, m.q_lora_rank, dt)
        p["w_uq"] = dense_init(kq2, m.q_lora_rank, h * qk_head, dt)
        p["norm_q"] = rmsnorm_init(m.q_lora_rank, dt)
    else:
        p["w_q"] = dense_init(ks[5], cfg.d_model, h * qk_head, dt)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   num_layers: Optional[int] = None) -> Params:
    """MLA latent cache: per position store c_kv [rank] + rotary key [rope_dim]."""
    m = cfg.mla
    dt = _dt(cfg)
    nl = num_layers if num_layers is not None else cfg.num_layers
    return {
        "ckv": jnp.zeros((nl, batch, cache_len, m.kv_lora_rank), dt),
        "kr": jnp.zeros((nl, batch, cache_len, m.qk_rope_head_dim), dt),
        "pos": -jnp.ones((nl, batch, cache_len), jnp.int32),
    }


def mla_apply(params: Params, x: jax.Array, *, cfg: ModelConfig,
              positions: jax.Array, cache: Optional[Params] = None,
              window: int = 0) -> Tuple[jax.Array, Optional[Params]]:
    """MLA attention; decode path uses the *absorbed* formulation against the
    latent cache (the memory saving that motivates MLA)."""
    m = cfg.mla
    b, lq, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    if m.q_lora_rank:
        q = rmsnorm(params["norm_q"], x @ params["w_dq"]) @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(b, lq, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(params["norm_ckv"], x @ params["w_dkv"])          # [B, L, rank]
    kr = (x @ params["w_kr"])[:, :, None, :]                        # [B, L, 1, dr]
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]      # [B, L, dr]

    scale = 1.0 / math.sqrt(dn + dr)

    new_cache = None
    if cache is not None:
        w = cache["ckv"].shape[1]
        pos_b = jnp.broadcast_to(positions, (lq,)).astype(jnp.int32)
        keep = pos_b >= (pos_b[-1] - w + 1)
        slots = jnp.broadcast_to(jnp.where(keep, pos_b % w, w), (b, lq))
        bidx = jnp.arange(b)[:, None]
        cckv = cache["ckv"].at[bidx, slots].set(
            ckv.astype(cache["ckv"].dtype), mode="drop")
        ckr = cache["kr"].at[bidx, slots].set(
            kr.astype(cache["kr"].dtype), mode="drop")
        cpos = cache["pos"].at[bidx, slots].set(
            jnp.broadcast_to(pos_b, (b, lq)), mode="drop")
        new_cache = {"ckv": cckv, "kr": ckr, "pos": cpos}

    if cache is not None and lq == 1:
        # absorbed decode: score = q_nope·(W_uk c) + q_rope·k_r
        #                = (q_nope W_uk^T)·c + q_rope·k_r
        cast = (lambda x: x.astype(jnp.float32)) if cfg.attn_cast_f32 \
            else (lambda x: x)
        w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, dn)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", cast(q_nope), cast(w_uk),
                           preferred_element_type=jnp.float32)      # [B,Lq,H,rank]
        s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(cckv.dtype),
                           cast(cckv), preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", cast(q_rope), cast(ckr),
                            preferred_element_type=jnp.float32)
        logits = (s_lat + s_rope) * scale
        q_pos = jnp.broadcast_to(positions, (b, lq))
        mask = _attn_scores_mask(q_pos, cpos, causal=True, window=window)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        # out_h = probs · v = probs · (W_uv c): aggregate latent then up-project
        lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(cckv.dtype) if not
                         cfg.attn_cast_f32 else probs, cast(cckv),
                         preferred_element_type=jnp.float32)
        w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, dv)
        out = jnp.einsum("bqhr,rhd->bqhd", lat.astype(w_uv.dtype) if not
                         cfg.attn_cast_f32 else lat, cast(w_uv),
                         preferred_element_type=jnp.float32)
        out = out.astype(x.dtype).reshape(b, lq, h * dv) @ params["w_o"]
        return out, new_cache

    # train / prefill: materialise k/v heads (standard formulation)
    k_nope = (ckv @ params["w_uk"]).reshape(b, lq, h, dn)
    vh = (ckv @ params["w_uv"]).reshape(b, lq, h, dv)
    kh = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, lq, h, dr))],
                         axis=-1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_pos = jnp.broadcast_to(positions, (b, lq))
    out = sdpa(qh, kh, vh, q_pos=q_pos, k_pos=q_pos, causal=True,
               window=window, scale=scale, cast_f32=cfg.attn_cast_f32)
    out = out.reshape(b, lq, h * dv) @ params["w_o"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             prefix: str = "") -> Params:
    dt = _dt(cfg)
    f = d_ff or cfg.d_ff
    gated = cfg.activation in ("silu", "gelu")
    ks = jax.random.split(key, 3)
    p = {
        prefix + "w_up": dense_init(ks[0], cfg.d_model, f, dt),
        prefix + "w_down": dense_init(ks[1], f, cfg.d_model, dt,
                                      scale=1.0 / math.sqrt(f * 2 * cfg.num_layers)),
    }
    if gated:
        p[prefix + "w_gate"] = dense_init(ks[2], cfg.d_model, f, dt)
    return p


def mlp_apply(params: Params, x: jax.Array, cfg: ModelConfig,
              prefix: str = "") -> jax.Array:
    act = _act(cfg.activation)
    up = x @ params[prefix + "w_up"]
    if prefix + "w_gate" in params:
        h = act(x @ params[prefix + "w_gate"]) * up
    else:
        h = act(up)
    h = sharding.constrain(h, "batch", None, "act_ffn")
    return h @ params[prefix + "w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    e = cfg.moe
    dt = _dt(cfg)
    f = e.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    sc_in = 1.0 / math.sqrt(d)
    sc_out = 1.0 / math.sqrt(f * 2 * cfg.num_layers)

    def expert_bank(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    p: Params = {
        "router": dense_init(ks[0], d, e.num_experts, jnp.float32, scale=sc_in),
        "moe_gate": expert_bank(ks[1], (e.num_experts, d, f), sc_in),
        "moe_up": expert_bank(ks[2], (e.num_experts, d, f), sc_in),
        "moe_down": expert_bank(ks[3], (e.num_experts, f, d), sc_out),
    }
    if e.num_shared_experts:
        fs = f * e.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared_gate"] = dense_init(k1, d, fs, dt, scale=sc_in)
        p["shared_up"] = dense_init(k2, d, fs, dt, scale=sc_in)
        p["shared_down"] = dense_init(k3, fs, d, dt, scale=sc_out)
    return p


def _route(params: Params, xf: jax.Array, e) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing.  xf: [T, d] f32.  Returns (probs [T,k], idx [T,k], aux)."""
    logits = xf @ params["router"]                                   # [T, E] f32
    full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(full, e.experts_per_token)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    counts = jnp.zeros((e.num_experts,), jnp.float32)
    counts = counts.at[idx.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = full.mean(axis=0)
    aux = e.num_experts * jnp.sum(frac_tokens * frac_probs) * e.router_aux_loss_coef
    return probs, idx, aux


def moe_apply_gather(params: Params, x: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-bucketed sort/gather MoE (single-host / GSPMD-auto path)."""
    e = cfg.moe
    b, sl, d = x.shape
    t = b * sl
    k = e.experts_per_token
    xf = x.reshape(t, d)
    probs, idx, aux = _route(params, xf.astype(jnp.float32), e)

    cap = int(math.ceil(t * k / e.num_experts * e.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)                                   # round up to 8

    e_flat = idx.reshape(-1)                                         # [T*k]
    t_flat = jnp.repeat(jnp.arange(t), k)
    g_flat = probs.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    se, st, sg = e_flat[order], t_flat[order], g_flat[order]
    starts = jnp.searchsorted(se, jnp.arange(e.num_experts))         # [E]
    slot = jnp.arange(t * k) - starts[se]
    ok = slot < cap
    dst = jnp.where(ok, se * cap + slot, e.num_experts * cap)        # overflow row

    buf = jnp.zeros((e.num_experts * cap + 1, d), x.dtype).at[dst].set(xf[st])
    h = buf[:-1].reshape(e.num_experts, cap, d)
    act = _act("silu")
    hg = jnp.einsum("ecd,edf->ecf", h, params["moe_gate"])
    hu = jnp.einsum("ecd,edf->ecf", h, params["moe_up"])
    ho = jnp.einsum("ecf,efd->ecd", act(hg) * hu, params["moe_down"])
    ho = jnp.concatenate([ho.reshape(e.num_experts * cap, d),
                          jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = ho[dst] * (sg * ok).astype(x.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[st].add(contrib)

    if e.num_shared_experts:
        out = out + _shared_expert(params, xf, cfg)
    return out.reshape(b, sl, d), aux


def _shared_expert(params: Params, xf: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _act("silu")
    h = act(xf @ params["shared_gate"]) * (xf @ params["shared_up"])
    return h @ params["shared_down"]


def moe_apply_ep(params: Params, x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map: experts live on the ``model`` axis,
    tokens are replicated across it; each shard computes only its experts and
    contributions are combined with a single psum (beyond-GSPMD perf path)."""
    mesh = sharding.active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply_gather(params, x, cfg)
    e = cfg.moe
    b, sl, d = x.shape
    t_global = b * sl
    k = e.experts_per_token
    ep = mesh.shape["model"]

    w_gate, w_up, w_down = (params["moe_gate"], params["moe_up"],
                            params["moe_down"])
    f_dim = w_gate.shape[-1]
    # routing outside shard_map (cheap, lets GSPMD place the [T, E] matmul)
    probs, idx, aux = _route(params, x.reshape(t_global, d).astype(jnp.float32), e)

    if e.num_experts % ep == 0:
        rep = 1
        e_eff, k_eff = e.num_experts, k
    elif ep % e.num_experts == 0:
        # fewer experts than shards: split each expert's FFN width into
        # ``rep`` chunks → E·rep "virtual experts" (sum-decomposable: the
        # gated MLP is additive over f-chunks through w_down) so every
        # shard owns exactly one virtual expert
        rep = ep // e.num_experts
        assert f_dim % rep == 0
        e_eff, k_eff = e.num_experts * rep, k * rep
        fr = f_dim // rep
        w_gate = w_gate.reshape(e.num_experts, d, rep, fr) \
            .swapaxes(1, 2).reshape(e_eff, d, fr)
        w_up = w_up.reshape(e.num_experts, d, rep, fr) \
            .swapaxes(1, 2).reshape(e_eff, d, fr)
        w_down = w_down.reshape(e.num_experts, rep, fr, d) \
            .reshape(e_eff, fr, d)
        idx = (idx[..., None] * rep
               + jnp.arange(rep)[None, None, :]).reshape(t_global, k_eff)
        probs = jnp.repeat(probs, rep, axis=-1)
    else:
        raise ValueError(f"experts={e.num_experts} incompatible with "
                         f"model axis {ep}")
    e_loc = e_eff // ep

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    x_spec = P(batch_axes, None, None)
    probs = probs.reshape(b, sl, k_eff)
    idx = idx.reshape(b, sl, k_eff)

    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    t_loc = t_global // n_batch_shards
    cap = int(math.ceil(t_loc * k / e.num_experts * e.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    def shard_fn(xb, pb, ib, wg, wu, wd):
        bb, ll, _ = xb.shape
        tl = bb * ll
        xl = xb.reshape(tl, d)
        pl = pb.reshape(tl * k_eff)
        il = ib.reshape(tl * k_eff)
        my = jax.lax.axis_index("model") * e_loc
        e_rel = il - my
        mine = (e_rel >= 0) & (e_rel < e_loc)
        sort_key = jnp.where(mine, e_rel, e_loc)     # sentinel e_loc = "not mine"
        order = jnp.argsort(sort_key, stable=True)
        se, sm = sort_key[order], mine[order]
        st = jnp.repeat(jnp.arange(tl), k_eff)[order]
        sg = pl[order]
        starts = jnp.searchsorted(se, jnp.arange(e_loc))
        slot = jnp.arange(tl * k_eff) - starts[jnp.clip(se, 0, e_loc - 1)]
        ok = sm & (slot < cap)
        dst = jnp.where(ok, jnp.clip(se, 0, e_loc - 1) * cap + slot, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), xb.dtype).at[dst].set(xl[st])
        h = buf[:-1].reshape(e_loc, cap, d)
        act = _act("silu")
        hg = jnp.einsum("ecd,edf->ecf", h, wg)
        hu = jnp.einsum("ecd,edf->ecf", h, wu)
        ho = jnp.einsum("ecf,efd->ecd", act(hg) * hu, wd)
        ho = jnp.concatenate([ho.reshape(e_loc * cap, d),
                              jnp.zeros((1, d), xb.dtype)], axis=0)
        contrib = ho[dst] * (sg * ok).astype(xb.dtype)[:, None]
        out = jnp.zeros((tl, d), xb.dtype).at[st].add(contrib)
        out = jax.lax.psum(out, "model")
        return out.reshape(bb, ll, d)

    out = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(x_spec, P(batch_axes, None, None), P(batch_axes, None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=x_spec,
        **_SHARD_MAP_KW,
    )(x, probs.astype(x.dtype), idx, w_gate, w_up, w_down)

    if e.num_shared_experts:
        xf = x.reshape(t_global, d)
        out = out + _shared_expert(params, xf, cfg).reshape(b, sl, d)
    return out, aux


def moe_apply(params: Params, x: jax.Array, cfg: ModelConfig, impl: str = "gather"
              ) -> Tuple[jax.Array, jax.Array]:
    if impl == "ep":
        return moe_apply_ep(params, x, cfg)
    return moe_apply_gather(params, x, cfg)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Params:
    dt = _dt(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok_embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                         jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return params["tok_embed"][tokens]


def unembed(params: Params, x: jax.Array) -> jax.Array:
    if "lm_head" in params:
        return x @ params["lm_head"]
    return x @ params["tok_embed"].T


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in f32. logits [..., V], targets [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
