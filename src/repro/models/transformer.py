"""Decoder-only transformer stack (dense / MoE / MLA / SWA / cross-attn).

One scanned homogeneous layer stack (``lax.scan`` over stacked params) keeps
the HLO compact so 40–95-layer configs lower/compile quickly on the 512-device
dry-run mesh.  VLM-style cross-attention interleaves are handled by scanning
over *groups* (N self layers + 1 cross layer).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


class TransformerLM:
    """Language model over integer tokens.

    Public API (shared by all model families in this repo):
      init(rng) -> params
      loss(params, batch, rng) -> (scalar_loss, metrics)
      forward(params, tokens, ...) -> logits
      prefill(params, tokens, cache_len) -> (logits_last, cache)
      decode_step(params, cache, tokens, pos) -> (logits, cache)
    """

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg
        self.moe_impl = moe_impl
        self.is_moe = cfg.moe is not None
        self.is_mla = cfg.attention == "mla"
        self.n_cross = (cfg.num_layers // cfg.cross_attn_every
                        if cfg.cross_attn_every else 0)

    # ------------------------------------------------------------- init ---
    def _layer_init(self, key) -> Params:
        cfg = self.cfg
        k_attn, k_ffn, k_n1, k_n2 = jax.random.split(key, 4)
        norm_init, _ = L.make_norm(cfg)
        p: Params = {
            "norm_attn": norm_init(cfg.d_model, L._dt(cfg)),
            "norm_ffn": norm_init(cfg.d_model, L._dt(cfg)),
        }
        if self.is_mla:
            p["attn"] = L.mla_init(k_attn, cfg)
        else:
            p["attn"] = L.attention_init(k_attn, cfg)
        if self.is_moe:
            p["moe"] = L.moe_init(k_ffn, cfg)
        else:
            p.update(L.mlp_init(k_ffn, cfg))
        return p

    def _cross_layer_init(self, key) -> Params:
        cfg = self.cfg
        norm_init, _ = L.make_norm(cfg)
        return {
            "norm_cross": norm_init(cfg.d_model, L._dt(cfg)),
            "attn": L.attention_init(key, cfg, cross=True),
            "gate_cross": jnp.zeros((), L._dt(cfg)),   # zero-init gated residual
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_e, k_l, k_c, k_f = jax.random.split(rng, 4)
        norm_init, _ = L.make_norm(cfg)
        n_scan = cfg.num_layers
        params: Params = {
            "embedding": L.embedding_init(k_e, cfg),
            "final_norm": norm_init(cfg.d_model, L._dt(cfg)),
            "layers": jax.vmap(self._layer_init)(jax.random.split(k_l, n_scan)),
        }
        if self.n_cross:
            params["cross_layers"] = jax.vmap(self._cross_layer_init)(
                jax.random.split(k_c, self.n_cross))
        return params

    # ---------------------------------------------------------- layers ----
    def _layer_apply(self, p: Params, x: jax.Array, positions: jax.Array,
                     cache: Optional[Params], window: int
                     ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
        cfg = self.cfg
        _, norm = L.make_norm(cfg)
        h = norm(p["norm_attn"], x)
        if self.is_mla:
            attn_out, new_cache = L.mla_apply(
                p["attn"], h, cfg=cfg, positions=positions, cache=cache,
                window=window)
        else:
            attn_out, new_cache = L.attention_apply(
                p["attn"], h, cfg=cfg, positions=positions, cache=cache,
                causal=True, window=window)
        x = x + attn_out
        h = norm(p["norm_ffn"], x)
        if self.is_moe:
            ffn_out, aux = L.moe_apply(p["moe"], h, cfg, impl=self.moe_impl)
        else:
            ffn_out, aux = L.mlp_apply(p, h, cfg), jnp.zeros((), jnp.float32)
        x = x + ffn_out
        x = sharding.constrain(x, "batch", None, None)
        return x, new_cache, aux

    def _cross_apply(self, p: Params, x: jax.Array, kv: jax.Array) -> jax.Array:
        cfg = self.cfg
        _, norm = L.make_norm(cfg)
        h = norm(p["norm_cross"], x)
        out, _ = L.attention_apply(p["attn"], h, cfg=cfg,
                                   positions=jnp.zeros((1,), jnp.int32),
                                   kv_input=kv, causal=False)
        return x + jnp.tanh(p["gate_cross"]).astype(x.dtype) * out

    # --------------------------------------------------------- forward ----
    def forward(self, params: Params, tokens: jax.Array, *,
                positions: Optional[jax.Array] = None,
                cache: Optional[Params] = None,
                image_embeds: Optional[jax.Array] = None,
                window: Optional[int] = None,
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
        """Returns (logits [B, L, V], new_cache, aux_loss)."""
        cfg = self.cfg
        b, lq = tokens.shape[0], tokens.shape[1]
        if positions is None:
            positions = jnp.arange(lq, dtype=jnp.int32)
        win = cfg.sliding_window if window is None else window

        tokens = sharding.constrain(tokens, "batch", None)
        x = L.embed(params["embedding"], tokens)
        x = sharding.constrain(x, "batch", None, None)

        layer_params = params["layers"]
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, xs):
            xc, aux = carry
            if cache is not None:
                lp, lc = xs
            else:
                lp, lc = xs, None
            xc, new_lc, a = self._layer_apply(lp, xc, positions, lc, win)
            new_lc = new_lc if new_lc is not None else 0
            return (xc, aux + a), new_lc

        body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body

        if self.n_cross:
            # group = cross_attn_every self layers + 1 cross layer
            g = cfg.cross_attn_every
            ng = self.n_cross
            grouped = jax.tree.map(
                lambda a: a.reshape((ng, g) + a.shape[1:]), layer_params)
            cross_params = params["cross_layers"]
            kv = image_embeds
            assert kv is not None, "vlm forward requires image_embeds"
            grouped_cache = (jax.tree.map(
                lambda a: a.reshape((ng, g) + a.shape[1:]), cache)
                if cache is not None else None)

            def group_body(carry, xs):
                if cache is not None:
                    gp, cp, gc = xs
                    (xc, aux), new_gc = jax.lax.scan(body_fn, carry, (gp, gc))
                else:
                    gp, cp = xs
                    (xc, aux), new_gc = jax.lax.scan(body_fn, carry, gp)
                xc = self._cross_apply(cp, xc, kv)
                return (xc, aux), new_gc

            xs = ((grouped, cross_params, grouped_cache) if cache is not None
                  else (grouped, cross_params))
            (x, aux), new_cache = jax.lax.scan(group_body, (x, aux0), xs)
            if cache is not None:
                new_cache = jax.tree.map(
                    lambda a: a.reshape((ng * g,) + a.shape[2:]), new_cache)
        else:
            xs = (layer_params, cache) if cache is not None else layer_params
            (x, aux), new_cache = jax.lax.scan(body_fn, (x, aux0), xs)

        x = L.make_norm(cfg)[1](params["final_norm"], x)
        logits = L.unembed(params["embedding"], x)
        logits = sharding.constrain(logits, "batch", None, "vocab")
        return logits, (new_cache if cache is not None else None), aux

    # ------------------------------------------------------------ loss ----
    def loss(self, params: Params, batch: Dict[str, jax.Array], rng=None
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        tokens = batch["tokens"]
        targets = batch["targets"]
        mask = batch.get("mask")
        logits, _, aux = self.forward(
            params, tokens, image_embeds=batch.get("image_embeds"))
        ce = L.cross_entropy(logits, targets, mask)
        return ce + aux, {"ce": ce, "aux": aux}

    def predict(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, _, _ = self.forward(params, batch["tokens"],
                                    image_embeds=batch.get("image_embeds"))
        return logits

    # ------------------------------------------------------- serving ------
    def init_cache(self, batch: int, cache_len: int) -> Params:
        cfg = self.cfg
        if self.is_mla:
            return L.init_mla_cache(cfg, batch, cache_len)
        return L.init_kv_cache(cfg, batch, cache_len)

    def prefill(self, params: Params, tokens: jax.Array, cache_len: int, *,
                image_embeds: Optional[jax.Array] = None,
                window: Optional[int] = None
                ) -> Tuple[jax.Array, Params]:
        b = tokens.shape[0]
        cache = self.init_cache(b, cache_len)
        logits, cache, _ = self.forward(params, tokens, cache=cache,
                                        image_embeds=image_embeds, window=window)
        return logits[:, -1:], cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array, *, image_embeds: Optional[jax.Array] = None,
                    window: Optional[int] = None) -> Tuple[jax.Array, Params]:
        """tokens [B, 1]; pos scalar int32 (absolute position of this token)."""
        positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
        logits, cache, _ = self.forward(params, tokens, positions=positions,
                                        cache=cache, image_embeds=image_embeds,
                                        window=window)
        return logits, cache
