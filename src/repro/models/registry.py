"""Model family registry: ``ModelConfig.family`` → builder."""
from __future__ import annotations

from repro.config import ModelConfig


def _dense(cfg, moe_impl="gather"):
    from repro.models.transformer import TransformerLM
    return TransformerLM(cfg, moe_impl)


def _moe(cfg, moe_impl="gather"):
    from repro.models.transformer import TransformerLM
    return TransformerLM(cfg, moe_impl)


def _ssm(cfg, moe_impl="gather"):
    from repro.models.ssm import Mamba2LM
    return Mamba2LM(cfg, moe_impl)


def _hybrid(cfg, moe_impl="gather"):
    from repro.models.hybrid import RecurrentGemmaLM
    return RecurrentGemmaLM(cfg, moe_impl)


def _vlm(cfg, moe_impl="gather"):
    from repro.models.vlm import VisionLM
    return VisionLM(cfg, moe_impl)


def _audio(cfg, moe_impl="gather"):
    from repro.models.audio import AudioLM
    return AudioLM(cfg, moe_impl)


def _small(cfg, moe_impl="gather"):
    from repro.models import small
    builders = {"mnist_dnn": small.MnistDNN, "lenet5": small.LeNet5,
                "char_lstm": small.CharLSTM}
    key = cfg.name.split("-")[0]
    for k, b in builders.items():
        if cfg.name.startswith(k):
            return b(cfg)
    raise ValueError(f"unknown small model {cfg.name!r}")


MODEL_FAMILIES = {
    "dense": _dense,
    "moe": _moe,
    "ssm": _ssm,
    "hybrid": _hybrid,
    "vlm": _vlm,
    "audio": _audio,
    "small": _small,
}


def build_model(cfg: ModelConfig, moe_impl: str = "gather"):
    if cfg.family not in MODEL_FAMILIES:
        raise ValueError(f"unknown model family {cfg.family!r} "
                         f"(have {sorted(MODEL_FAMILIES)})")
    return MODEL_FAMILIES[cfg.family](cfg, moe_impl)
