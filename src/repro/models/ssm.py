"""Mamba-2 (SSD — state-space duality) stack. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm (chunk-local quadratic term +
inter-chunk linear state recurrence); decode is the O(1)/token recurrent step.
Attention-free: the natural sub-quadratic citizen for ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    return d_inner, nheads, s.head_dim, s.state_dim


def segsum(x: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum_{j < m <= i} x[..., m].

    Returns -inf above the diagonal (used as log-decay matrix L).
    """
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int, initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x  [B, L, H, P]   inputs (per-head channels)
    dt [B, L, H]      positive step sizes
    a  [H]            negative per-head decay rates
    b  [B, L, N]      input projections (shared across heads, G=1)
    c  [B, L, N]      output projections
    Returns (y [B, L, H, P], final_state [B, H, P, N]).
    """
    bs, sl, h, p = x.shape
    n = b.shape[-1]
    l_orig = sl
    if sl % chunk:
        # zero-pad to a chunk multiple: dt=0 at pads ⇒ decay 1, update 0 —
        # the state is provably unaffected by padding positions
        pad = chunk - sl % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        sl = sl + pad
    nc = sl // chunk

    xr = x.reshape(bs, nc, chunk, h, p)
    dtr = dt.reshape(bs, nc, chunk, h)
    br = b.reshape(bs, nc, chunk, n)
    cr = c.reshape(bs, nc, chunk, n)
    da = dtr * a                                                     # [B,NC,Q,H] (<0)
    da = jnp.moveaxis(da, -1, -2)                                    # [B,NC,H,Q]

    # 1) intra-chunk (quadratic within the chunk)
    lmat = jnp.exp(segsum(da))                                       # [B,NC,H,Q,Q]
    scores = jnp.einsum("bzin,bzjn->bzij", cr, br)                   # [B,NC,Q,Q]
    xdt = xr * dtr[..., None]                                        # x * dt
    y_intra = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, lmat, xdt)

    # 2) chunk summaries: decay from step j to end of chunk = exp(sum_{m>j} da_m)
    cum = jnp.cumsum(da, axis=-1)                                    # [B,NC,H,Q]
    decay_end = jnp.exp(cum[..., -1:] - cum)                         # [B,NC,H,Q]
    states = jnp.einsum("bzjn,bzhj,bzjhp->bzhpn", br, decay_end, xdt)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[..., -1])                              # [B,NC,H]
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bs, h, p, n), x.dtype))

    def step(s_prev, inp):
        dec, st = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    (s_final, s_prevs) = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32),
         jnp.moveaxis(states, 1, 0).astype(jnp.float32)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                            # [B,NC,H,P,N]

    # 4) contribution of previous-chunk state to each position
    in_decay = jnp.exp(cum)                              # decay from chunk start
    y_inter = jnp.einsum("bzin,bzhi,bzhpn->bzihp", cr, in_decay,
                         s_prevs.astype(cr.dtype))

    y = (y_intra + y_inter).reshape(bs, sl, h, p)[:, :l_orig]
    return y.astype(x.dtype), s_final.astype(x.dtype)


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
             b: jax.Array, c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent step. state [B,H,P,N]; x [B,H,P]; dt [B,H]; b,c [B,N]."""
    da = jnp.exp(dt * a)                                             # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], b)
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c)
    return state, y


class Mamba2LM:
    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg

    # ------------------------------------------------------------- init ---
    def _layer_init(self, key) -> Params:
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        dt = L._dt(cfg)
        conv_dim = d_inner + 2 * n
        ks = jax.random.split(key, 4)
        proj_out = 2 * d_inner + 2 * n + h                           # z, x, B, C, dt
        return {
            "norm_attn": L.rmsnorm_init(cfg.d_model, dt),
            "in_proj": L.dense_init(ks[0], cfg.d_model, proj_out, dt),
            "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_width, conv_dim),
                                         jnp.float32) / math.sqrt(cfg.ssm.conv_width)
                       ).astype(dt),
            "conv_b": jnp.zeros((conv_dim,), dt),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
            "dt_bias": jnp.zeros((h,), jnp.float32),
            "D_skip": jnp.ones((h,), jnp.float32),
            "norm_gate": L.rmsnorm_init(d_inner, dt),
            "out_proj": L.dense_init(ks[2], d_inner, cfg.d_model, dt,
                                     scale=1.0 / math.sqrt(d_inner * cfg.num_layers)),
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_e, k_l = jax.random.split(rng)
        return {
            "embedding": L.embedding_init(k_e, cfg),
            "final_norm": L.rmsnorm_init(cfg.d_model, L._dt(cfg)),
            "layers": jax.vmap(self._layer_init)(
                jax.random.split(k_l, cfg.num_layers)),
        }

    # -------------------------------------------------------- internals ---
    def _split_proj(self, zxbcdt):
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        z = zxbcdt[..., :d_inner]
        xbc = zxbcdt[..., d_inner:2 * d_inner + 2 * n]
        dt_raw = zxbcdt[..., 2 * d_inner + 2 * n:]
        return z, xbc, dt_raw

    def _layer_train(self, pl: Params, x: jax.Array) -> jax.Array:
        """Full-sequence SSD mixing for one layer."""
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        resid = x
        xn = L.rmsnorm(pl["norm_attn"], x)
        z, xbc, dt_raw = self._split_proj(xn @ pl["in_proj"])
        # causal depthwise conv (width W): pad left
        w = cfg.ssm.conv_width
        pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + xbc.shape[1], :] * pl["conv_w"][i][None, None, :]
                   for i in range(w)) + pl["conv_b"]
        xbc = jax.nn.silu(conv)
        xs = xbc[..., :d_inner].reshape(x.shape[0], x.shape[1], h, p)
        b = xbc[..., d_inner:d_inner + n]
        c = xbc[..., d_inner + n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])
        a = -jnp.exp(pl["A_log"])
        y, _ = ssd_chunked(xs.astype(jnp.float32), dt, a,
                           b.astype(jnp.float32), c.astype(jnp.float32),
                           cfg.ssm.chunk_size)
        y = y + pl["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(x.shape[0], x.shape[1], d_inner).astype(x.dtype)
        y = L.rmsnorm(pl["norm_gate"], y * jax.nn.silu(z))
        return resid + y @ pl["out_proj"]

    # --------------------------------------------------------- forward ----
    def forward(self, params: Params, tokens: jax.Array, **_kw):
        cfg = self.cfg
        x = L.embed(params["embedding"], tokens)
        x = sharding.constrain(x, "batch", None, None)

        def body(xc, pl):
            f = self._layer_train
            if cfg.remat:
                f = jax.checkpoint(f)
            return f(pl, xc), 0

        x, _ = jax.lax.scan(body, x, params["layers"])
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embedding"], x)
        return logits, None, jnp.zeros((), jnp.float32)

    def loss(self, params, batch, rng=None):
        logits, _, _ = self.forward(params, batch["tokens"])
        ce = L.cross_entropy(logits, batch["targets"], batch.get("mask"))
        return ce, {"ce": ce}

    def predict(self, params, batch):
        return self.forward(params, batch["tokens"])[0]

    # ------------------------------------------------------- serving ------
    def init_cache(self, batch: int, cache_len: int = 0) -> Params:
        """Recurrent cache: conv tail + SSM state per layer (cache_len unused —
        state is O(1) in sequence length)."""
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        conv_dim = d_inner + 2 * n
        dt = L._dt(cfg)
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm.conv_width - 1,
                               conv_dim), dt),
            "state": jnp.zeros((cfg.num_layers, batch, h, p, n), dt),
        }

    def _layer_step(self, pl: Params, lc: Params, x: jax.Array
                    ) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        resid = x
        xn = L.rmsnorm(pl["norm_attn"], x)                           # [B,1,d]
        z, xbc, dt_raw = self._split_proj(xn @ pl["in_proj"])
        xbc1 = xbc[:, 0, :]                                          # [B,convdim]
        hist = jnp.concatenate([lc["conv"], xbc1[:, None, :]], axis=1)
        conv = jnp.einsum("bwc,wc->bc", hist, pl["conv_w"]) + pl["conv_b"]
        new_conv = hist[:, 1:, :]
        u = jax.nn.silu(conv)
        xs = u[:, :d_inner].reshape(-1, h, p)
        b = u[:, d_inner:d_inner + n]
        c = u[:, d_inner + n:]
        dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + pl["dt_bias"])
        a = -jnp.exp(pl["A_log"])
        state, y = ssd_step(lc["state"].astype(jnp.float32),
                            xs.astype(jnp.float32), dt, a,
                            b.astype(jnp.float32), c.astype(jnp.float32))
        y = y + pl["D_skip"][None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(-1, 1, d_inner).astype(x.dtype)
        y = L.rmsnorm(pl["norm_gate"], y * jax.nn.silu(z))
        out = resid + y @ pl["out_proj"]
        return out, {"conv": new_conv.astype(lc["conv"].dtype),
                     "state": state.astype(lc["state"].dtype)}

    def prefill(self, params: Params, tokens: jax.Array, cache_len: int = 0,
                **_kw) -> Tuple[jax.Array, Params]:
        """Prefill = full SSD pass that also materialises the recurrent cache."""
        cfg = self.cfg
        d_inner, h, p, n = _dims(cfg)
        x = L.embed(params["embedding"], tokens)
        bsz, lq = tokens.shape

        def body(xc, pl):
            resid = xc
            xn = L.rmsnorm(pl["norm_attn"], xc)
            z, xbc, dt_raw = self._split_proj(xn @ pl["in_proj"])
            w = cfg.ssm.conv_width
            pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
            conv = sum(pad[:, i:i + lq, :] * pl["conv_w"][i][None, None, :]
                       for i in range(w)) + pl["conv_b"]
            conv_tail = pad[:, -(w - 1):, :] if w > 1 else pad[:, :0, :]
            u = jax.nn.silu(conv)
            xs = u[..., :d_inner].reshape(bsz, lq, h, p)
            b = u[..., d_inner:d_inner + n]
            c = u[..., d_inner + n:]
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])
            a = -jnp.exp(pl["A_log"])
            y, s_final = ssd_chunked(xs.astype(jnp.float32), dt, a,
                                     b.astype(jnp.float32), c.astype(jnp.float32),
                                     cfg.ssm.chunk_size)
            y = y + pl["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
            y = y.reshape(bsz, lq, d_inner).astype(xc.dtype)
            y = L.rmsnorm(pl["norm_gate"], y * jax.nn.silu(z))
            out = resid + y @ pl["out_proj"]
            return out, {"conv": conv_tail.astype(xc.dtype),
                         "state": s_final.astype(xc.dtype)}

        x, cache = jax.lax.scan(body, x, params["layers"])
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embedding"], x[:, -1:])
        return logits, cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array, **_kw) -> Tuple[jax.Array, Params]:
        x = L.embed(params["embedding"], tokens)                     # [B,1,d]

        def body(xc, xs):
            pl, lc = xs
            out, new_lc = self._layer_step(pl, lc, xc)
            return out, new_lc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embedding"], x)
        return logits, new_cache
