"""MusicGen-style audio decoder over EnCodec tokens. [arXiv:2306.05284]

The EnCodec neural codec itself is a STUB per the assignment — the model
consumes/produces discrete codec tokens directly.  MusicGen's delay-pattern
multi-codebook stream is modelled with K parallel codebooks: input embedding
is the sum of per-codebook embeddings; output is K parallel LM heads.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.transformer import TransformerLM

Params = Dict[str, Any]


class AudioLM(TransformerLM):
    """tokens have shape [B, L, K] (K = num_audio_codebooks)."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        assert cfg.num_audio_codebooks > 0
        super().__init__(cfg, moe_impl)
        self.k_cb = cfg.num_audio_codebooks

    def init(self, rng) -> Params:
        cfg = self.cfg
        params = super().init(rng)
        k1, k2 = jax.random.split(jax.random.fold_in(rng, 7))
        dt = L._dt(cfg)
        # per-codebook embeddings + heads replace the single-stream ones
        params["embedding"] = {
            "tok_embed": (jax.random.normal(
                k1, (self.k_cb, cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(dt),
            "lm_head": (jax.random.normal(
                k2, (self.k_cb, cfg.d_model, cfg.vocab_size), jnp.float32)
                / math.sqrt(cfg.d_model)).astype(dt),
        }
        return params

    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        # tokens [B, L, K] → sum_k embed_k(tokens[..., k])
        emb = params["embedding"]["tok_embed"]                        # [K, V, d]
        onehot_free = jnp.take_along_axis  # noqa — we use fancy indexing below
        parts = [emb[i][tokens[..., i]] for i in range(self.k_cb)]
        return sum(parts)

    def _unembed(self, params: Params, x: jax.Array) -> jax.Array:
        # [B, L, d] → [B, L, K, V]
        return jnp.einsum("bld,kdv->blkv", x, params["embedding"]["lm_head"])

    def forward(self, params: Params, tokens: jax.Array, *, positions=None,
                cache=None, image_embeds=None, window=None):
        cfg = self.cfg
        b, lq = tokens.shape[0], tokens.shape[1]
        if positions is None:
            positions = jnp.arange(lq, dtype=jnp.int32)
        win = cfg.sliding_window if window is None else window
        x = self._embed(params, tokens)
        x = sharding.constrain(x, "batch", None, None)
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, xs):
            xc, aux = carry
            if cache is not None:
                lp, lc = xs
            else:
                lp, lc = xs, None
            xc, new_lc, a = self._layer_apply(lp, xc, positions, lc, win)
            return (xc, aux + a), (new_lc if new_lc is not None else 0)

        body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
        xs = (params["layers"], cache) if cache is not None else params["layers"]
        (x, aux), new_cache = jax.lax.scan(body_fn, (x, aux0), xs)
        x = L.make_norm(cfg)[1](params["final_norm"], x)
        logits = self._unembed(params, x)
        return logits, (new_cache if cache is not None else None), aux

    def loss(self, params, batch, rng=None):
        logits, _, aux = self.forward(params, batch["tokens"])       # [B,L,K,V]
        targets = batch["targets"]                                   # [B,L,K]
        mask = batch.get("mask")
        if mask is not None:
            mask = mask[..., None] * jnp.ones_like(targets, jnp.float32)
        ce = L.cross_entropy(logits, targets, mask)
        return ce + aux, {"ce": ce, "aux": aux}

    def predict(self, params, batch):
        return self.forward(params, batch["tokens"])[0]
