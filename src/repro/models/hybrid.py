"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention,
interleaved 2:1 (two recurrent blocks, then one local-MQA block). [arXiv:2402.19427]

The linear recurrence h_t = a_t h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` at train/prefill time and as an O(1) step at
decode time — natively sub-quadratic for ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]

_C_RGLRU = 8.0   # Griffin's fixed exponent scale


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_scan(u: jax.Array, log_a: jax.Array, gate_i: jax.Array,
               h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Gated linear recurrence over time.

    u       [B, L, W]  inputs (post input-gate)
    log_a   [B, L, W]  per-step log decay (≤ 0)
    gate_i  [B, L, W]  input gate in [0, 1]
    Returns (h [B, L, W], h_last [B, W]).
    """
    a = jnp.exp(log_a)
    # multiplier sqrt(1 - a^2), computed stably from log a
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (gate_i * u)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_step(h: jax.Array, u: jax.Array, log_a: jax.Array, gate_i: jax.Array
               ) -> jax.Array:
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a * h + mult * (gate_i * u)


class RecurrentGemmaLM:
    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        self.cfg = cfg
        pat = cfg.hybrid.pattern
        assert pat.count("attn") == 1 and len(pat) == 3, "expect 2 rglru : 1 attn"
        self.group = len(pat)
        self.n_groups = cfg.num_layers // self.group
        self.n_tail = cfg.num_layers - self.n_groups * self.group   # extra rglru

    # ------------------------------------------------------------- init ---
    def _rec_layer_init(self, key) -> Params:
        cfg = self.cfg
        w = _lru_width(cfg)
        dt = L._dt(cfg)
        ks = jax.random.split(key, 6)
        return {
            "norm_attn": L.rmsnorm_init(cfg.d_model, dt),
            "lru_in": L.dense_init(ks[0], cfg.d_model, w, dt),
            "lru_in_gate": L.dense_init(ks[1], cfg.d_model, w, dt),
            "conv_w": (jax.random.normal(ks[2], (4, w), jnp.float32) / 2.0).astype(dt),
            "conv_b": jnp.zeros((w,), dt),
            "lru_gate_a": L.dense_init(ks[3], w, w, dt),
            "lru_gate_i": L.dense_init(ks[4], w, w, dt),
            # Λ init so a^c ∈ (0.9, 0.999)-ish
            "lru_a": jnp.log(jnp.expm1(
                -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C_RGLRU)).astype(jnp.float32),
            "lru_out": L.dense_init(ks[5], w, cfg.d_model, dt,
                                    scale=1.0 / math.sqrt(w * cfg.num_layers)),
            "norm_ffn": L.rmsnorm_init(cfg.d_model, dt),
            **L.mlp_init(key, cfg),
        }

    def _attn_layer_init(self, key) -> Params:
        cfg = self.cfg
        dt = L._dt(cfg)
        return {
            "norm_attn": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.attention_init(key, cfg),
            "norm_ffn": L.rmsnorm_init(cfg.d_model, dt),
            **L.mlp_init(key, cfg),
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_e, k_r, k_a, k_t = jax.random.split(rng, 4)
        k_rs = jax.random.split(k_r, self.n_groups * 2)
        k_rs = k_rs.reshape((self.n_groups, 2) + k_rs.shape[1:])
        p: Params = {
            "embedding": L.embedding_init(k_e, cfg),
            "final_norm": L.rmsnorm_init(cfg.d_model, L._dt(cfg)),
            # stacked [n_groups, 2, ...] recurrent layers and [n_groups] attn
            "rec_layers": jax.vmap(jax.vmap(self._rec_layer_init))(k_rs),
            "attn_layers": jax.vmap(self._attn_layer_init)(
                jax.random.split(k_a, self.n_groups)),
        }
        if self.n_tail:
            p["tail_layers"] = jax.vmap(self._rec_layer_init)(
                jax.random.split(k_t, self.n_tail))
        return p

    # ---------------------------------------------------------- blocks ----
    def _rec_apply(self, pl: Params, x: jax.Array, *,
                   conv_state: Optional[jax.Array] = None,
                   h_state: Optional[jax.Array] = None, decode: bool = False
                   ) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
        cfg = self.cfg
        resid = x
        xn = L.rmsnorm(pl["norm_attn"], x)
        u = xn @ pl["lru_in"]                                        # [B,L,W]
        gate_branch = jax.nn.gelu(xn @ pl["lru_in_gate"])
        bsz, lq, w = u.shape
        cw = pl["conv_w"].shape[0]
        if decode:
            hist = jnp.concatenate([conv_state, u], axis=1)          # [B,cw,W]
            u_c = jnp.einsum("bwc,wc->bc", hist, pl["conv_w"]) + pl["conv_b"]
            u_c = u_c[:, None, :]
            new_conv = hist[:, 1:, :]
        else:
            pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
            u_c = sum(pad[:, i:i + lq, :] * pl["conv_w"][i][None, None, :]
                      for i in range(cw)) + pl["conv_b"]
            new_conv = pad[:, -(cw - 1):, :]
        r = jax.nn.sigmoid(u_c @ pl["lru_gate_a"]).astype(jnp.float32)
        gi = jax.nn.sigmoid(u_c @ pl["lru_gate_i"]).astype(jnp.float32)
        log_a = -_C_RGLRU * jax.nn.softplus(pl["lru_a"])[None, None, :] * r
        uf = u_c.astype(jnp.float32)
        if decode:
            h = rglru_step(h_state.astype(jnp.float32), uf[:, 0, :],
                           log_a[:, 0, :], gi[:, 0, :])
            hseq = h[:, None, :]
            h_last = h
        else:
            hseq, h_last = rglru_scan(uf, log_a, gi,
                                      h0=None if h_state is None
                                      else h_state.astype(jnp.float32))
        y = (hseq.astype(x.dtype) * gate_branch) @ pl["lru_out"]
        x = resid + y
        h2 = L.rmsnorm(pl["norm_ffn"], x)
        x = x + L.mlp_apply(pl, h2, cfg)
        return x, new_conv.astype(x.dtype), h_last.astype(x.dtype)

    def _attn_apply(self, pl: Params, x: jax.Array, positions, cache, window
                    ) -> Tuple[jax.Array, Optional[Params]]:
        cfg = self.cfg
        h = L.rmsnorm(pl["norm_attn"], x)
        out, new_cache = L.attention_apply(pl["attn"], h, cfg=cfg,
                                           positions=positions, cache=cache,
                                           causal=True, window=window)
        x = x + out
        h = L.rmsnorm(pl["norm_ffn"], x)
        x = x + L.mlp_apply(pl, h, cfg)
        return x, new_cache

    # --------------------------------------------------------- forward ----
    def forward(self, params: Params, tokens: jax.Array, *,
                positions: Optional[jax.Array] = None, cache=None, **_kw):
        cfg = self.cfg
        lq = tokens.shape[1]
        if positions is None:
            positions = jnp.arange(lq, dtype=jnp.int32)
        window = cfg.hybrid.attention_window
        x = L.embed(params["embedding"], tokens)
        x = sharding.constrain(x, "batch", None, None)

        def rec_one(rp, xi):
            out, _, _ = self._rec_apply(rp, xi)
            return out

        def attn_one(ap, xi):
            out, _ = self._attn_apply(ap, xi, positions, None, window)
            return out

        if cfg.remat:
            rec_one = jax.checkpoint(rec_one)
            attn_one = jax.checkpoint(attn_one)

        def group_body(xc, gp):
            rec_p, attn_p = gp
            xc, _ = jax.lax.scan(lambda xi, rp: (rec_one(rp, xi), 0), xc, rec_p)
            return attn_one(attn_p, xc), 0

        x, _ = jax.lax.scan(group_body, x,
                            (params["rec_layers"], params["attn_layers"]))
        if self.n_tail:
            def tail_body(xc, rp):
                out, _, _ = self._rec_apply(rp, xc)
                return out, 0
            x, _ = jax.lax.scan(tail_body, x, params["tail_layers"])
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embedding"], x)
        return logits, None, jnp.zeros((), jnp.float32)

    def loss(self, params, batch, rng=None):
        logits, _, _ = self.forward(params, batch["tokens"])
        ce = L.cross_entropy(logits, batch["targets"], batch.get("mask"))
        return ce, {"ce": ce}

    def predict(self, params, batch):
        return self.forward(params, batch["tokens"])[0]

    # ------------------------------------------------------- serving ------
    def init_cache(self, batch: int, cache_len: int) -> Params:
        cfg = self.cfg
        w = _lru_width(cfg)
        dt = L._dt(cfg)
        window = min(cache_len, cfg.hybrid.attention_window)
        hd = cfg.resolved_head_dim
        n_rec_total = self.n_groups * 2 + self.n_tail
        return {
            "conv": jnp.zeros((n_rec_total, batch, 3, w), dt),
            "h": jnp.zeros((n_rec_total, batch, w), dt),
            "attn": L.init_kv_cache(cfg, batch, window,
                                    num_layers=self.n_groups),
        }

    def _run_with_cache(self, params: Params, tokens: jax.Array,
                        cache: Params, positions, decode: bool
                        ) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        window = cfg.hybrid.attention_window
        x = L.embed(params["embedding"], tokens)
        n_rec = self.n_groups * 2

        rec_caches = {k: cache[k][:n_rec].reshape(
            (self.n_groups, 2) + cache[k].shape[1:]) for k in ("conv", "h")}

        def group_body(xc, xs):
            rec_p, attn_p, rc, ac = xs

            def rec_body(xi, inner):
                rp, rcc = inner
                out, new_conv, new_h = self._rec_apply(
                    rp, xi, conv_state=rcc["conv"],
                    h_state=rcc["h"] if decode else None, decode=decode)
                return out, {"conv": new_conv, "h": new_h}

            xc, new_rc = jax.lax.scan(rec_body, xc, (rec_p, rc))
            xc, new_ac = self._attn_apply(attn_p, xc, positions, ac, window)
            return xc, (new_rc, new_ac)

        x, (new_rec, new_attn) = jax.lax.scan(
            group_body, x,
            (params["rec_layers"], params["attn_layers"],
             {"conv": rec_caches["conv"], "h": rec_caches["h"]}, cache["attn"]))

        new_cache = {
            "conv": new_rec["conv"].reshape((n_rec,) + new_rec["conv"].shape[2:]),
            "h": new_rec["h"].reshape((n_rec,) + new_rec["h"].shape[2:]),
            "attn": new_attn,
        }
        if self.n_tail:
            def tail_body(xc, xs):
                rp, cv, hh = xs
                out, ncv, nh = self._rec_apply(rp, xc, conv_state=cv,
                                               h_state=hh if decode else None,
                                               decode=decode)
                return out, (ncv, nh)
            x, (tcv, th) = jax.lax.scan(
                tail_body, x, (params["tail_layers"],
                               cache["conv"][n_rec:], cache["h"][n_rec:]))
            new_cache["conv"] = jnp.concatenate([new_cache["conv"], tcv], 0)
            new_cache["h"] = jnp.concatenate([new_cache["h"], th], 0)
        x = L.rmsnorm(params["final_norm"], x)
        return x, new_cache

    def prefill(self, params: Params, tokens: jax.Array, cache_len: int,
                **_kw) -> Tuple[jax.Array, Params]:
        b, lq = tokens.shape
        cache = self.init_cache(b, cache_len)
        positions = jnp.arange(lq, dtype=jnp.int32)
        x, cache = self._run_with_cache(params, tokens, cache, positions,
                                        decode=False)
        logits = L.unembed(params["embedding"], x[:, -1:])
        return logits, cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array, **_kw) -> Tuple[jax.Array, Params]:
        positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
        x, cache = self._run_with_cache(params, tokens, cache, positions,
                                        decode=True)
        logits = L.unembed(params["embedding"], x)
        return logits, cache
