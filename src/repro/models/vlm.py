"""Llama-3.2-Vision-style VLM text decoder. [hf:meta-llama/Llama-3.2-11B-Vision]

The vision frontend (ViT encoder + projector) is a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings [B, N_img, d_model];
this module implements the language decoder with gated cross-attention layers
inserted every ``cfg.cross_attn_every`` self-attention layers.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.transformer import TransformerLM

Params = Dict[str, Any]


class VisionLM(TransformerLM):
    """TransformerLM + mandatory image embeddings through cross-attention."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "gather"):
        assert cfg.cross_attn_every > 0, "vlm requires cross_attn_every"
        super().__init__(cfg, moe_impl)

    def stub_image_embeds(self, batch: int, dtype=None) -> jax.Array:
        """Deterministic stand-in for the ViT+projector output."""
        cfg = self.cfg
        n = cfg.num_image_tokens or 576
        dt = dtype or jnp.dtype(cfg.dtype)
        base = jnp.arange(n * cfg.d_model, dtype=jnp.float32)
        emb = jnp.sin(base * 0.001).reshape(n, cfg.d_model) * 0.02
        return jnp.broadcast_to(emb[None], (batch, n, cfg.d_model)).astype(dt)

    def predict(self, params, batch):
        image_embeds = batch.get("image_embeds")
        if image_embeds is None:
            image_embeds = self.stub_image_embeds(batch["tokens"].shape[0])
        logits, _, _ = self.forward(params, batch["tokens"],
                                    image_embeds=image_embeds)
        return logits

    def loss(self, params, batch, rng=None):
        tokens = batch["tokens"]
        image_embeds = batch.get("image_embeds")
        if image_embeds is None:
            image_embeds = self.stub_image_embeds(tokens.shape[0])
        logits, _, aux = self.forward(params, tokens, image_embeds=image_embeds)
        from repro.models import layers as L
        ce = L.cross_entropy(logits, batch["targets"], batch.get("mask"))
        return ce + aux, {"ce": ce, "aux": aux}

    def prefill(self, params, tokens, cache_len, *, image_embeds=None, window=None):
        if image_embeds is None:
            image_embeds = self.stub_image_embeds(tokens.shape[0])
        return super().prefill(params, tokens, cache_len,
                               image_embeds=image_embeds, window=window)

    def decode_step(self, params, cache, tokens, pos, *, image_embeds=None,
                    window=None):
        if image_embeds is None:
            image_embeds = self.stub_image_embeds(tokens.shape[0])
        return super().decode_step(params, cache, tokens, pos,
                                   image_embeds=image_embeds, window=window)
