from repro.models.registry import build_model, MODEL_FAMILIES
