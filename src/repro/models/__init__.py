from repro.models.registry import MODEL_FAMILIES, build_model

__all__ = ["MODEL_FAMILIES", "build_model"]
