from repro.data.synthetic import (
    synthetic_mnist, synthetic_cifar, synthetic_shakespeare, synthetic_lm_corpus,
)
from repro.data.partition import partition_noniid, ClientDataset
