from repro.data.partition import ClientDataset, partition_noniid
from repro.data.synthetic import (
    synthetic_cifar,
    synthetic_lm_corpus,
    synthetic_mnist,
    synthetic_shakespeare,
)

__all__ = [
    "ClientDataset",
    "partition_noniid",
    "synthetic_cifar",
    "synthetic_lm_corpus",
    "synthetic_mnist",
    "synthetic_shakespeare",
]
