"""Non-i.i.d. federated partitioning — Sec. VI-A-3 of the paper.

Each UE is allocated a different local data size and holds exactly ``n_labels`` of
the label classes (the non-iid level; smaller = more heterogeneous).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class ClientDataset:
    """One UE's local dataset + batch sampler (train/test split)."""
    data: Dict[str, np.ndarray]
    test: Dict[str, np.ndarray]
    labels_held: np.ndarray
    rng: np.random.Generator

    def __len__(self) -> int:
        return len(next(iter(self.data.values())))

    def sample(self, batch_size: int) -> Dict[str, np.ndarray]:
        n = len(self)
        idx = self.rng.integers(0, n, size=min(batch_size, n))
        return {k: v[idx] for k, v in self.data.items()}

    def triplet_sizes(self, b_in: int, b_o: int, b_h: int
                      ) -> "tuple[int, int, int]":
        """Actual (inner, outer, hessian) batch sizes ``sample_triplet``
        will return — the truncation rule lives HERE, next to the sampler,
        so shape-compatibility checks can't drift from it."""
        n = len(self)
        return (min(b_in, n), min(b_o, n), min(b_h, n))

    def drift_labels(self, rng: np.random.Generator, frac: float,
                     label_key: str = "y") -> int:
        """Non-stationary label drift (scenario suite): remap a random
        ``frac`` of this client's samples under a random permutation of
        the classes it holds, in BOTH splits — the personalized eval
        then reflects the drifted distribution, not the drop-time one.

        Draws only from the caller's ``rng`` (the scenario stream), never
        from the private sampling generator, so enabling drift does not
        perturb the batch-index schedule of undrifted clients.  Returns
        the number of samples whose label actually changed (0 when the
        client holds fewer than two classes, or has no label field).
        """
        if label_key not in self.data:
            return 0
        classes = np.unique(np.concatenate(
            [self.data[label_key], self.test[label_key]]))
        if len(classes) < 2:
            return 0
        perm = classes[rng.permutation(len(classes))]
        lut = np.zeros(int(classes.max()) + 1, dtype=classes.dtype)
        lut[classes] = perm
        changed = 0
        for split in (self.data, self.test):
            y = split[label_key]
            pick = rng.random(len(y)) < frac
            new_y = np.where(pick, lut[y], y)
            changed += int(np.count_nonzero(new_y != y))
            split[label_key] = new_y
        self.labels_held = np.unique(self.data[label_key])
        return changed

    def sample_triplet(self, b_in: int, b_o: int, b_h: int) -> Dict[str, Dict]:
        """Three *independent* batches (D_in, D_o, D_h of Eq. 7).

        Drawn as ONE index vector + one gather per field, then sliced into
        the three views — the simulator calls this once per arrival, so it
        sits on the event-loop hot path.
        """
        s_in, s_o, s_h = self.triplet_sizes(b_in, b_o, b_h)
        idx = self.rng.integers(0, len(self), size=s_in + s_o + s_h)
        full = {k: v[idx] for k, v in self.data.items()}
        return {"inner": {k: v[:s_in] for k, v in full.items()},
                "outer": {k: v[s_in:s_in + s_o] for k, v in full.items()},
                "hessian": {k: v[s_in + s_o:] for k, v in full.items()}}


def sample_triplet_many(clients: List[ClientDataset], b_in: int, b_o: int,
                        b_h: int) -> Dict[str, Dict[str, np.ndarray]]:
    """Stacked ``sample_triplet`` for several clients in ONE pass.

    Returns the same ``{"inner"/"outer"/"hessian": {field: array}}`` layout
    with a leading client axis, gathered straight into preallocated stacked
    buffers — the batch-wise driver feed hands these to the engine without
    re-stacking per lane.  Each client consumes exactly the one
    ``rng.integers`` call ``sample_triplet`` would, in list order, so the
    result is bitwise identical to the per-UE loop (each ``ClientDataset``
    owns a private generator).  All clients must share triplet sizes and
    field shapes (the driver groups lanes by shape signature first).
    """
    if not clients:
        raise ValueError("sample_triplet_many needs at least one client")
    s_in, s_o, s_h = clients[0].triplet_sizes(b_in, b_o, b_h)
    total = s_in + s_o + s_h
    m = len(clients)
    stacked: Dict[str, np.ndarray] = {
        k: np.empty((m, total) + v.shape[1:], dtype=v.dtype)
        for k, v in clients[0].data.items()}
    for i, c in enumerate(clients):
        if c.triplet_sizes(b_in, b_o, b_h) != (s_in, s_o, s_h):
            raise ValueError("sample_triplet_many: mixed triplet sizes — "
                             "group clients by shape signature first")
        idx = c.rng.integers(0, len(c), size=total)
        for k, v in c.data.items():
            np.take(v, idx, axis=0, out=stacked[k][i])
    return {"inner": {k: v[:, :s_in] for k, v in stacked.items()},
            "outer": {k: v[:, s_in:s_in + s_o] for k, v in stacked.items()},
            "hessian": {k: v[:, s_in + s_o:] for k, v in stacked.items()}}


def partition_noniid(data: Dict[str, np.ndarray], n_clients: int,
                     n_labels: int,
                     *, n_classes: Optional[int] = None, seed: int = 0,
                     label_key: str = "y", test_frac: float = 0.2,
                     size_spread: float = 3.0) -> List[ClientDataset]:
    """Partition ``data`` so each client holds exactly ``n_labels`` classes.

    Shards per class are split round-robin among the clients holding that
    class; client sizes vary by up to ``size_spread``× (paper: "each UE is
    allocated a different local data size").
    """
    rng = np.random.default_rng(seed)
    y = data[label_key]
    classes = np.unique(y) if n_classes is None else np.arange(n_classes)
    n_cls = len(classes)
    n_labels = max(1, min(n_labels, n_cls))

    # assign exactly n_labels distinct classes per client; spread coverage by
    # preferring the least-held classes (classes no client holds stay unused
    # — with n·n_labels < n_classes full coverage is impossible anyway)
    held_count = {int(c): 0 for c in classes}
    client_classes = []
    for _ in range(n_clients):
        order = sorted(classes, key=lambda c: (held_count[int(c)],
                                               rng.random()))
        mine = np.array(sorted(order[:n_labels]))
        for c in mine:
            held_count[int(c)] += 1
        client_classes.append(mine)

    # holders per class
    holders: Dict[int, List[int]] = {int(c): [] for c in classes}
    for ci, cls in enumerate(client_classes):
        for c in cls:
            holders[int(c)].append(ci)

    # heterogeneous size weights
    weights = np.exp(rng.uniform(0, np.log(size_spread), size=n_clients))

    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx_c = np.where(y == c)[0]
        rng.shuffle(idx_c)
        hs = holders[int(c)]
        if not hs:
            continue
        w = weights[hs] / weights[hs].sum()
        cuts = np.floor(np.cumsum(w) * len(idx_c)).astype(int)
        prev = 0
        for hi, cut in zip(hs, cuts):
            client_idx[hi].extend(idx_c[prev:cut].tolist())
            prev = cut

    out: List[ClientDataset] = []
    for ci in range(n_clients):
        idx = np.array(sorted(client_idx[ci]), dtype=np.int64)
        if len(idx) < 4:                   # guarantee a usable shard — pad
            pool = np.where(np.isin(y, client_classes[ci]))[0]
            extra = rng.choice(pool, size=8)    # ...from the SAME n_labels classes
            idx = np.concatenate([idx, extra])
        rng.shuffle(idx)
        n_test = max(1, int(len(idx) * test_frac))
        test_idx, train_idx = idx[:n_test], idx[n_test:]
        out.append(ClientDataset(
            data={k: v[train_idx] for k, v in data.items()},
            test={k: v[test_idx] for k, v in data.items()},
            labels_held=np.unique(y[train_idx]),
            rng=np.random.default_rng(seed * 1000 + ci + 1),
        ))
    return out


def sequence_clients(role_data: Dict[int, Dict[str, np.ndarray]],
                     n_clients: int, seed: int = 0,
                     test_frac: float = 0.2) -> List[ClientDataset]:
    """Shakespeare-style: each client = one role's sequences."""
    roles = sorted(role_data)[:n_clients]
    out = []
    for ci, role in enumerate(roles):
        d = role_data[role]
        n = len(d["tokens"])
        n_test = max(1, int(n * test_frac))
        out.append(ClientDataset(
            data={k: v[n_test:] for k, v in d.items()},
            test={k: v[:n_test] for k, v in d.items()},
            labels_held=np.array([role]),
            rng=np.random.default_rng(seed * 1000 + ci + 1),
        ))
    return out
