"""Synthetic stand-ins for the paper's datasets (offline container — no
downloads).  Each generator produces *learnable* class/sequence structure so
training curves are meaningful, with per-class signal strong enough that the
paper's qualitative phenomena (personalization gain, non-iid degradation)
reproduce.

* ``synthetic_mnist``       — 10-class Gaussian prototypes in 28×28
* ``synthetic_cifar``       — 100-class colored pattern prototypes in 32×32×3
* ``synthetic_shakespeare`` — per-role Markov character streams (80-char vocab)
* ``synthetic_lm_corpus``   — Zipfian bigram token stream for LLM examples
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def synthetic_mnist(n: int = 6000, n_classes: int = 10, seed: int = 0,
                    noise: float = 0.35) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(n_classes, 28, 28)).astype(np.float32)
    # low-pass the prototypes so they look like smooth strokes
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, -1, 1)
                  + np.roll(protos, 1, 2) + np.roll(protos, -1, 2)) / 5.0
    protos /= np.abs(protos).max(axis=(1, 2), keepdims=True)
    y = rng.integers(0, n_classes, size=n)
    x = protos[y] + noise * rng.normal(size=(n, 28, 28)).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


def synthetic_cifar(n: int = 6000, n_classes: int = 100, seed: int = 1,
                    noise: float = 0.4) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(n_classes, 32, 32, 3)).astype(np.float32)
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, -1, 1)
                  + np.roll(protos, 1, 2) + np.roll(protos, -1, 2)) / 5.0
    protos /= np.abs(protos).max(axis=(1, 2, 3), keepdims=True)
    y = rng.integers(0, n_classes, size=n)
    x = protos[y] + noise * rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


def synthetic_shakespeare(n_roles: int = 188, chars_per_role: int = 2000,
                          vocab: int = 80, seq_len: int = 32, seed: int = 2
                          ) -> Dict[int, Dict[str, np.ndarray]]:
    """Per-role character streams from role-specific Markov chains.

    LEAF's Shakespeare is non-iid by speaking role; we mirror that: each role
    has its own transition matrix (shared backbone + role-specific
    perturbation), so the next-char distribution differs per client.
    Returns {role: {"tokens": [n_seq, L], "targets": [n_seq, L]}}.
    """
    rng = np.random.default_rng(seed)
    base = rng.dirichlet(np.full(vocab, 0.3), size=vocab)
    out: Dict[int, Dict[str, np.ndarray]] = {}
    for role in range(n_roles):
        pert = rng.dirichlet(np.full(vocab, 0.15), size=vocab)
        trans = 0.6 * base + 0.4 * pert
        trans /= trans.sum(1, keepdims=True)
        stream = np.empty(chars_per_role, dtype=np.int32)
        stream[0] = rng.integers(vocab)
        for t in range(1, chars_per_role):
            stream[t] = rng.choice(vocab, p=trans[stream[t - 1]])
        n_seq = (chars_per_role - 1) // seq_len
        toks = stream[:n_seq * seq_len].reshape(n_seq, seq_len)
        targ = stream[1:n_seq * seq_len + 1].reshape(n_seq, seq_len)
        out[role] = {"tokens": toks, "targets": targ}
    return out


def conflicting_label_clients(n_clients: int, n_per_client: int = 300,
                              n_classes: int = 10, n_swap: int = 4,
                              seed: int = 0, noise: float = 0.35):
    """Clients share the input distribution but each permutes ``n_swap`` of
    the labels — no single global model fits everyone, while a meta-learned
    initialisation can adapt to each client in one gradient step.  This is
    the regime where PFL provably beats FL (the paper's motivation §I).

    Returns a list of {"x", "y"} dicts (feed to ClientDataset manually or
    via ``partition_noniid`` per client)."""
    rng = np.random.default_rng(seed)
    base = synthetic_mnist(n=n_per_client * n_clients, n_classes=n_classes,
                           seed=seed, noise=noise)
    out = []
    for ci in range(n_clients):
        sl = slice(ci * n_per_client, (ci + 1) * n_per_client)
        x, y = base["x"][sl], base["y"][sl].copy()
        swap = rng.choice(n_classes, size=n_swap, replace=False)
        perm = swap[np.argsort(rng.random(n_swap))]
        lut = np.arange(n_classes)
        lut[swap] = perm
        out.append({"x": x, "y": lut[y].astype(np.int32)})
    return out


def synthetic_lm_corpus(n_tokens: int = 1 << 16, vocab: int = 512,
                        seed: int = 3) -> np.ndarray:
    """Zipfian bigram stream — enough structure for loss curves to move."""
    rng = np.random.default_rng(seed)
    # sparse bigram table: each token strongly prefers a few successors
    succ = rng.integers(0, vocab, size=(vocab, 4))
    zipf_p = 1.0 / np.arange(1, 5)
    zipf_p /= zipf_p.sum()
    stream = np.empty(n_tokens, dtype=np.int32)
    stream[0] = rng.integers(vocab)
    choices = rng.random(n_tokens)
    uniform = rng.integers(0, vocab, size=n_tokens)
    picks = rng.choice(4, p=zipf_p, size=n_tokens)
    for t in range(1, n_tokens):
        if choices[t] < 0.8:
            stream[t] = succ[stream[t - 1], picks[t]]
        else:
            stream[t] = uniform[t]
    return stream
