"""Simulator observability: tracing, per-round telemetry, reporting.

``obs.trace``    — ``Tracer`` (nestable phase spans, counters, blocking
                   device attribution), the module-level no-op singleton
                   that makes disabled tracing near-free, the leveled
                   ``Reporter``, and the optional ``jax.profiler`` hooks.
``obs.recorder`` — per-round record assembly + JSONL schema validation.

Enable per run via ``run_simulation(..., tracer=Tracer())`` /
``trace_dir="runs/trace"``, or through ``ExperimentConfig.obs``.
"""
from repro.obs.recorder import RoundRecorder, validate_rows
from repro.obs.trace import (NOOP, NoopTracer, Reporter, Tracer, current,
                             profile_trace, use)

__all__ = ["Tracer", "NoopTracer", "Reporter", "RoundRecorder", "NOOP",
           "current", "use", "profile_trace", "validate_rows"]
