"""Structured tracing for the simulator stack (near-zero disabled cost).

One substrate replaces the scattered ad-hoc timing that used to live in
one-off monkey patches (``benchmarks/event_loop.py``'s hand-rolled
``perf_counter`` guards around engine/protocol dispatches): a ``Tracer``
with

* **nestable phase spans** — ``with tracer.span("pricing"): ...``
  accumulates *exclusive* host seconds per phase (a child span's time is
  subtracted from its parent), so ``sum(phase_s.values())`` can never
  exceed the run's wall clock;
* **monotonic counters** — ``tracer.add("mobility.ticks", 3)``;
* **device attribution** — ``tracer.device_call("engine", fn, *args)``
  runs ``fn`` and, when ``device_timing`` is on, blocks on its output and
  books the elapsed time as *device* seconds under the given name
  (reentrancy-guarded: the fused round path runs the engine INSIDE the
  protocol call, and only the outermost timed frame may accumulate, or
  device time double-counts — the guard that used to be
  ``benchmarks/event_loop._SPLIT_GUARD``).

**Disabled fast path.** ``CURRENT`` is a module-level singleton that
defaults to ``NOOP`` — a tracer whose ``span`` returns one shared no-op
context manager and whose ``add``/``device_call`` do nothing.  Hot-loop
call sites read ``trace.CURRENT`` (one attribute fetch) and pay a couple
of empty method calls; no allocation, no branching on config, no timing
syscalls.  The per-heap-pop path of the event loop deliberately contains
NO tracing calls at all — mobility integration is instrumented inside its
(rare) tick branch instead.

**Read-only contract.** Nothing in the simulator reads wall-clock time
into the simulation (the simulated clock is pure event math), so tracing
— including the blocking device guard — can never perturb a trajectory:
all bitwise golden tests pass with tracing fully enabled
(``tests/test_obs.py``).

Optional ``jax.profiler`` hooks: a ``Tracer(profile=True)`` wraps every
span in a ``jax.profiler.TraceAnnotation`` so spans show up on the
TensorBoard trace timeline, and ``profile_trace(logdir)`` brackets a run
with ``start_trace``/``stop_trace`` to produce a TensorBoard-loadable
profile.
"""
from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, Optional

__all__ = ["Tracer", "NoopTracer", "Reporter", "NOOP", "CURRENT",
           "current", "use", "profile_trace"]


# ---------------------------------------------------------------------------
# disabled fast path
# ---------------------------------------------------------------------------

class _NoopSpan:
    """The one shared no-op context manager every disabled span returns."""
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """Disabled tracer: every call site costs one attribute check."""
    __slots__ = ()
    enabled = False
    device_timing = False

    def span(self, name: str) -> _NoopSpan:
        return _NOOP_SPAN

    def add(self, name: str, n: int = 1) -> None:
        return None

    def device_call(self, name: str, fn: Callable, *args: Any,
                    **kw: Any) -> Any:
        return fn(*args, **kw)

    def snapshot(self) -> Dict[str, Any]:
        return {"phase_s": {}, "counts": {}, "device_s": 0.0,
                "device_phase_s": {}}


NOOP = NoopTracer()

# module-level singleton: instrumentation sites read ``trace.CURRENT``
# directly; ``use()`` installs a live tracer for the duration of a run
CURRENT: Any = NOOP


def current() -> Any:
    """The tracer instrumentation sites currently feed (NOOP when off)."""
    return CURRENT


@contextmanager
def use(tracer: Optional["Tracer"]) -> Iterator[Any]:
    """Install ``tracer`` as the process-wide ``CURRENT`` for the block."""
    global CURRENT
    prev = CURRENT
    CURRENT = tracer if tracer is not None else NOOP
    try:
        yield CURRENT
    finally:
        CURRENT = prev


# ---------------------------------------------------------------------------
# live tracer
# ---------------------------------------------------------------------------

class _Span:
    """One phase frame; exclusive-time accounting via the tracer stack."""
    __slots__ = ("tr", "name", "t0", "child_s", "_ann")

    def __init__(self, tr: "Tracer", name: str):
        self.tr = tr
        self.name = name

    def __enter__(self) -> "_Span":
        self.child_s = 0.0
        self._ann = None
        if self.tr.profile:
            ann = _annotation(self.name)
            if ann is not None:
                ann.__enter__()
                self._ann = ann
        self.tr._stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        dt = time.perf_counter() - self.t0
        tr = self.tr
        tr._stack.pop()
        phase = tr.phase_s
        # exclusive: child spans (and blocking device frames) already own
        # their share of ``dt``
        phase[self.name] = phase.get(self.name, 0.0) \
            + max(dt - self.child_s, 0.0)
        if tr._stack:
            tr._stack[-1].child_s += dt
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


def _annotation(name: str) -> Optional[Any]:
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class Tracer:
    """Span/counter/device-time accumulator for one (or more) runs.

    ``device=True`` turns on the blocking device guard: every
    ``device_call`` blocks until its output is ready and the elapsed time
    is booked as device seconds (host seconds = wall − device).  Off by
    default — tracing then never forces synchronization, and
    ``device_s`` stays 0 (async dispatch overlap makes an unblocked split
    meaningless).

    ``profile=True`` additionally wraps spans in
    ``jax.profiler.TraceAnnotation`` — pair with ``profile_trace(logdir)``
    for a TensorBoard-loadable timeline.
    """
    enabled = True

    def __init__(self, *, device: bool = False, profile: bool = False):
        self.device_timing = bool(device)
        self.profile = bool(profile)
        self.phase_s: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.device_s = 0.0
        self.device_phase_s: Dict[str, float] = {}
        self._stack: list = []
        self._dev_depth = 0

    # -- spans ----------------------------------------------------------
    def span(self, name: str):
        if self._dev_depth:
            # inside a blocking device frame every second is already
            # attributed to that frame — a host span here would double-
            # book (e.g. ``cloud_sync`` under the ``protocol`` guard)
            return _NOOP_SPAN
        return _Span(self, name)

    # -- counters -------------------------------------------------------
    def add(self, name: str, n: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + int(n)

    # -- device attribution --------------------------------------------
    def device_call(self, name: str, fn: Callable, *args: Any,
                    **kw: Any) -> Any:
        """Run ``fn`` and attribute its wall time (including blocking on
        its output) to device seconds under ``name``.  Nested timed
        frames pass through untimed — only the outermost accumulates."""
        if not self.device_timing or self._dev_depth:
            return fn(*args, **kw)
        import jax
        self._dev_depth += 1
        t0 = time.perf_counter()
        try:
            out = fn(*args, **kw)
            jax.block_until_ready(out)
            return out
        finally:
            self._dev_depth -= 1
            dt = time.perf_counter() - t0
            self.device_s += dt
            self.device_phase_s[name] = \
                self.device_phase_s.get(name, 0.0) + dt
            if self._stack:
                # device time spent inside an open span is not that
                # span's host time
                self._stack[-1].child_s += dt

    # -- snapshots ------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Copy of all accumulators (the recorder diffs these per round)."""
        return {"phase_s": dict(self.phase_s),
                "counts": dict(self.counts),
                "device_s": self.device_s,
                "device_phase_s": dict(self.device_phase_s)}


@contextmanager
def profile_trace(logdir: Optional[str]) -> Iterator[None]:
    """Bracket a run with ``jax.profiler.start_trace``/``stop_trace`` so
    it produces a TensorBoard-loadable profile under ``logdir``.  A falsy
    ``logdir`` (or an unavailable profiler) degrades to a no-op."""
    if not logdir:
        yield
        return
    try:
        import jax.profiler as jp
        jp.start_trace(logdir)
    except Exception:
        yield
        return
    try:
        yield
    finally:
        try:
            jp.stop_trace()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# leveled progress reporting
# ---------------------------------------------------------------------------

_LEVELS = {"quiet": 0, "progress": 1, "debug": 2}


class Reporter:
    """Leveled run reporter replacing the driver's ad-hoc ``print``.

    ``quiet`` emits nothing, ``progress`` the per-eval summary lines the
    old ``verbose=True`` printed (byte-identical format), ``debug``
    additionally per-round close diagnostics.
    """

    def __init__(self, level: str = "quiet", stream: Any = None):
        if level not in _LEVELS:
            raise ValueError(f"unknown report level {level!r}; "
                             f"known: {sorted(_LEVELS)}")
        self.level = _LEVELS[level]
        self.stream = stream

    def _emit(self, msg: str) -> None:
        print(msg, file=self.stream or sys.stdout, flush=True)

    def warn(self, msg: str) -> None:
        """Anomaly reporting (aborted rounds, invariant near-misses):
        emitted at every level including ``quiet`` — losing work silently
        is exactly the failure mode this exists to surface."""
        self._emit(f"WARNING: {msg}")

    def progress(self, msg: str) -> None:
        if self.level >= _LEVELS["progress"]:
            self._emit(msg)

    def debug(self, msg: str) -> None:
        if self.level >= _LEVELS["debug"]:
            self._emit(msg)
