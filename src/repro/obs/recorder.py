"""Per-round telemetry records: assembly, JSONL flushing, validation.

``RoundRecorder`` sits in the event-loop driver and, at every round
close, diffs the live ``Tracer`` (and the engine's lifetime dispatch
counters) against the previous round's snapshot, assembling one
self-contained record:

    round index · closing cell · per-cell participation A_c (the arrived
    UE set) · staleness histogram at the close · heap depth · handover /
    departed-arrival deltas · dispatch counts by kind · per-phase host
    seconds · device seconds · wall seconds since the previous close

Records flush through ``utils.metrics.MetricsLogger`` (append-only JSONL,
one flush per record) when a trace directory is given, and an end-of-run
summary — totals plus the trace path — is attached to
``SimResult.telemetry`` either way.  ``validate_rows`` checks the schema
and the per-round invariants (phase seconds sum ≤ wall; Σ A_c = consumed
arrivals) and backs both ``scripts/trace_report.py --check`` and the unit
tests.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import numpy as np

SCHEMA = "perfeds2-trace-v1"

# keys every per-round record must carry (the JSONL contract
# ``scripts/trace_report.py --check`` enforces)
REQUIRED_KEYS = ("round", "cell", "a", "ues", "distributed",
                 "staleness_hist", "heap_depth", "t_sim", "wall_s",
                 "phase_s", "device_s", "dispatches", "payloads",
                 "eval_dispatches", "handovers", "departed_arrivals",
                 "cloud_rounds", "counts")

# staleness histogram cap: τ beyond this lands in the last bucket (the
# forced-refresh rule bounds live τ by S, so this never truncates in
# practice; hierarchy sentinel versions clip from below at 0)
STALE_HIST_CAP = 32


def _delta_map(now: Dict[str, float], then: Dict[str, float]
               ) -> Dict[str, float]:
    return {k: v - then.get(k, 0) for k, v in now.items()
            if v != then.get(k, 0)}


def staleness_histogram(stale_row: np.ndarray,
                        cap: int = STALE_HIST_CAP) -> List[int]:
    """Counts of UEs at each staleness 0..cap (τ ≥ cap folds into the
    last bucket; sentinel/negative values clip to 0)."""
    tau = np.clip(np.asarray(stale_row, dtype=np.int64), 0, cap)
    return np.bincount(tau, minlength=cap + 1).tolist()


class RoundRecorder:
    """Assemble one telemetry record per closed round by snapshot diffs."""

    def __init__(self, tracer: Any, engine: Any = None,
                 logger: Any = None):
        self.tracer = tracer
        self.engine = engine
        self.logger = logger
        self.records: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._t_last = self._t0
        self._mark = tracer.snapshot()
        self._eng_mark = self._engine_counters()
        self._extras_mark: Dict[str, int] = {}

    def _engine_counters(self) -> Dict[str, int]:
        e = self.engine
        if e is None:
            return {"dispatches": 0, "payloads": 0, "eval_dispatches": 0}
        return {"dispatches": e.dispatches,
                "payloads": e.payloads_computed,
                "eval_dispatches": e.eval_dispatches}

    # ------------------------------------------------------------------
    def on_round(self, *, result: Dict[str, Any], ues: np.ndarray,
                 heap_depth: int, extras: Dict[str, Any], t_sim: float,
                 staleness: np.ndarray,
                 members: Optional[List[int]] = None) -> Dict[str, Any]:
        """Record the round ``result`` just returned by the protocol;
        ``ues``/``staleness`` are read off the closing server's Π /
        staleness history (observability never writes protocol state).

        ``members`` — live per-protocol-cell membership counts under an
        open-world scenario; recorded as the OPTIONAL ``cell_members``
        key (closed-world traces omit it, so existing traces stay valid
        against the v1 schema).

        The record's wall/phase deltas cover everything since the
        previous close (including that round's redistribution and eval) —
        the tail after the final close lands in the summary only.
        """
        now = time.perf_counter()
        snap = self.tracer.snapshot()
        eng = self._engine_counters()
        rec: Dict[str, Any] = {
            "round": int(result["round"]),
            "cell": int(result.get("cell", 0)),
            "a": int(len(ues)),
            "ues": [int(u) for u in ues],
            "distributed": len(result.get("distribute", ())),
            "staleness_hist": staleness_histogram(staleness),
            "heap_depth": int(heap_depth),
            "t_sim": float(t_sim),
            "wall_s": now - self._t_last,
            "phase_s": _delta_map(snap["phase_s"], self._mark["phase_s"]),
            "device_s": snap["device_s"] - self._mark["device_s"],
            "dispatches": eng["dispatches"] - self._eng_mark["dispatches"],
            "payloads": eng["payloads"] - self._eng_mark["payloads"],
            "eval_dispatches": eng["eval_dispatches"]
            - self._eng_mark["eval_dispatches"],
            "handovers": int(extras.get("handovers", 0))
            - self._extras_mark.get("handovers", 0),
            "departed_arrivals": int(extras.get("departed_arrivals", 0))
            - self._extras_mark.get("departed_arrivals", 0),
            "cloud_rounds": int(extras.get("cloud_rounds", 0))
            - self._extras_mark.get("cloud_rounds", 0),
            "counts": _delta_map(snap["counts"], self._mark["counts"]),
        }
        if members is not None:
            rec["cell_members"] = [int(m) for m in members]
        self._t_last = now
        self._mark = snap
        self._eng_mark = eng
        self._extras_mark = {k: int(extras.get(k, 0))
                             for k in ("handovers", "departed_arrivals",
                                       "cloud_rounds")}
        self.records.append(rec)
        if self.logger is not None:
            self.logger.log(**rec)
        return rec

    # ------------------------------------------------------------------
    def finalize(self, extras: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
        """End-of-run summary (attached to ``SimResult.telemetry``); the
        ``_summary`` JSONL row is written and the logger closed."""
        snap = self.tracer.snapshot()
        summary: Dict[str, Any] = {
            "schema": SCHEMA,
            "rounds": len(self.records),
            "arrivals": int(sum(r["a"] for r in self.records)),
            "wall_s": time.perf_counter() - self._t0,
            "phase_s": snap["phase_s"],
            "device_s": snap["device_s"],
            "device_phase_s": snap["device_phase_s"],
            "counts": snap["counts"],
            "per_cell_a": self._per_cell_a(),
        }
        if extras:
            summary.update({k: int(v) for k, v in extras.items()})
        if self.logger is not None:
            self.logger._write({"_summary": _jsonable(summary)})
            summary["trace_path"] = self.logger.path
            self.logger.close()
        return summary

    def _per_cell_a(self) -> Dict[str, int]:
        per: Dict[str, int] = {}
        for r in self.records:
            key = str(r["cell"])
            per[key] = per.get(key, 0) + r["a"]
        return per


def _jsonable(v: Any) -> Any:
    from repro.utils.metrics import _plain
    return _plain(v)


# ---------------------------------------------------------------------------
# schema validation (shared by trace_report --check and the tests)
# ---------------------------------------------------------------------------

def split_rows(rows: List[Dict[str, Any]]):
    """(meta, round_records, summary) from raw ``read_metrics`` rows."""
    meta = rows[0].get("_meta") if rows and "_meta" in rows[0] else None
    summary = rows[-1].get("_summary") \
        if rows and "_summary" in rows[-1] else None
    recs = [r for r in rows if "_meta" not in r and "_summary" not in r]
    return meta, recs, summary


def validate_rows(rows: List[Dict[str, Any]],
                  wall_tol: float = 0.05) -> List[str]:
    """Schema + invariant check of one trace; returns a list of problems
    (empty = valid).

    Invariants: required keys present and sane; round indices strictly
    increasing; per-record Σ phase_s ≤ wall_s (within ``wall_tol``
    slack for timer granularity); Σ A_c over rounds equals the summary's
    consumed-arrival count.
    """
    errs: List[str] = []
    meta, recs, summary = split_rows(rows)
    if meta is None:
        errs.append("missing _meta header row")
    elif meta.get("schema") != SCHEMA:
        errs.append(f"_meta.schema is {meta.get('schema')!r}, "
                    f"want {SCHEMA!r}")
    if not recs:
        errs.append("no per-round records")
    prev_round = 0
    for i, r in enumerate(recs):
        missing = [k for k in REQUIRED_KEYS if k not in r]
        if missing:
            errs.append(f"record {i}: missing keys {missing}")
            continue
        if not isinstance(r["round"], int) or r["round"] <= prev_round:
            errs.append(f"record {i}: round {r['round']!r} not strictly "
                        f"increasing after {prev_round}")
        prev_round = r["round"] if isinstance(r["round"], int) \
            else prev_round
        if r["a"] < 1 or r["a"] != len(r["ues"]):
            errs.append(f"record {i}: a={r['a']} inconsistent with "
                        f"{len(r['ues'])} ues")
        if any(v < 0 for v in r["phase_s"].values()):
            errs.append(f"record {i}: negative phase seconds")
        host = sum(r["phase_s"].values())
        budget = r["wall_s"] * (1.0 + wall_tol) + 1e-6
        if host > budget:
            errs.append(f"record {i}: phase seconds {host:.6f} exceed "
                        f"wall {r['wall_s']:.6f}")
        if r["device_s"] > budget:
            errs.append(f"record {i}: device seconds {r['device_s']:.6f} "
                        f"exceed wall {r['wall_s']:.6f}")
        if sum(r["staleness_hist"]) <= 0:
            errs.append(f"record {i}: empty staleness histogram")
        if "cell_members" in r:        # optional (open-world scenarios)
            cm = r["cell_members"]
            if not isinstance(cm, list) or any(
                    not isinstance(v, int) or v < 0 for v in cm):
                errs.append(f"record {i}: cell_members must be a list of "
                            f"non-negative ints, got {cm!r}")
    if summary is None:
        errs.append("missing _summary trailer row")
    elif recs:
        tot = sum(r["a"] for r in recs)
        if summary.get("arrivals") != tot:
            errs.append(f"summary arrivals {summary.get('arrivals')} != "
                        f"Σ per-round a {tot}")
        if summary.get("rounds") != len(recs):
            errs.append(f"summary rounds {summary.get('rounds')} != "
                        f"{len(recs)} records")
    return errs
