"""Dependency-free pytree checkpointing (.npz + structure descriptor).

Arrays are gathered to host and stored in a single compressed npz; the pytree
structure is recorded as a flat list of '/'-joined key paths so restore
round-trips nested dicts / lists / NamedTuple-like structures of arrays.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    """Flatten to {path: np.array}; bf16 (not a numpy dtype) is stored as a
    uint16 bit-view with the true dtype recorded separately."""
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = flat
    out = {}
    dtypes: Dict[str, str] = {}
    for path, leaf in leaves:
        key = "/".join(_part(p) for p in path)
        arr = jnp.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            dtypes[key] = "bfloat16"
            out[key] = np.asarray(arr.view(jnp.uint16))
        else:
            out[key] = np.asarray(arr)
    return out, treedef, dtypes


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, tree: Any, *, step: Optional[int] = None,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Save a pytree of arrays. ``path`` is a directory; returns the file."""
    os.makedirs(path, exist_ok=True)
    arrays, _, dtypes = _flatten(tree)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz" if step is not None
                         else "ckpt.npz")
    meta = {"keys": sorted(arrays), "step": step, "extra": extra or {},
            "dtypes": dtypes}
    np.savez_compressed(fname, __meta__=json.dumps(meta), **arrays)
    return fname


def load_checkpoint(fname: str, like: Any = None) -> Any:
    """Restore. With ``like`` given, arrays are poured into its structure
    (dtype/shape-checked); otherwise returns a nested dict."""
    with np.load(fname, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        arrays = {}
        for k in meta["keys"]:
            a = z[k]
            if meta.get("dtypes", {}).get(k) == "bfloat16":
                a = jnp.asarray(a).view(jnp.bfloat16)
            arrays[k] = a
    if like is None:
        root: Dict[str, Any] = {}
        for key, arr in arrays.items():
            node = root
            parts = key.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(arr)
        return root
    flat_like, treedef, _ = _flatten(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_like, td = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    new_leaves = []
    for (path, leaf) in paths:
        key = "/".join(_part(p) for p in path)
        arr = arrays[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(td, new_leaves)


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    pat = re.compile(r"ckpt_(\d+)\.npz$")
    best, best_step = None, -1
    for f in os.listdir(path):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(path, f), int(m.group(1))
    if best is None and os.path.exists(os.path.join(path, "ckpt.npz")):
        return os.path.join(path, "ckpt.npz")
    return best
