"""Llama-3.2-11B-Vision — text decoder w/ cross-attn image layers
(vision frontend stubbed).  [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    max_seq_len=131072,
    attention="gqa",
    rope_theta=5e5,
    activation="silu",
    cross_attn_every=5,         # 8 cross-attention layers over 40 self layers
    num_image_tokens=1601,      # 1 tile × (40×40 patches + 1 cls)
    long_context_window=4096,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
