"""Paper model: LSTM next-character classifier for Shakespeare (Sec. VI-A)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="char_lstm",
    family="small",
    num_layers=1,
    d_model=256,                # LSTM hidden
    vocab_size=80,              # LEAF Shakespeare charset
    dtype="float32",
    source="paper Sec. VI-A (Shakespeare), LEAF benchmark",
)
