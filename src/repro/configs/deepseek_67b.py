"""DeepSeek-67B — dense llama-arch, GQA (64H/8KV). [arXiv:2401.02954]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    max_seq_len=4096,
    attention="gqa",
    rope_theta=1e4,
    activation="silu",
    long_context_window=4096,
    source="arXiv:2401.02954",
)
