"""Paper model: LeNet-5 for CIFAR-100 (Sec. VI-A)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="lenet5",
    family="small",
    num_layers=5,
    d_model=120,
    vocab_size=100,             # classes
    dtype="float32",
    source="paper Sec. VI-A (CIFAR-100), LeCun et al. 1998",
)
