"""Nemotron-4-15B — dense, GQA (48H/8KV), squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    max_seq_len=4096,
    attention="gqa",
    rope_theta=1e4,
    activation="sq_relu",       # squared-ReLU, non-gated MLP
    long_context_window=4096,
    source="arXiv:2402.16819",
)
