"""Mixtral-8x22B — MoE 8 experts top-2, GQA (48H/8KV), SWA. [arXiv:2401.04088]"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    max_seq_len=65536,
    attention="gqa",
    rope_theta=1e6,
    sliding_window=4096,        # native SWA → long_500k runs natively
    long_context_window=4096,
    activation="silu",
    moe=MoEConfig(num_experts=8, experts_per_token=2, expert_d_ff=16384),
    source="arXiv:2401.04088",
)
