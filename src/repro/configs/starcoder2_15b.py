"""StarCoder2-15B — dense, GQA (48H/4KV), RoPE. [arXiv:2402.19173]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    max_seq_len=16384,
    attention="gqa",
    rope_theta=1e5,
    activation="gelu",
    long_context_window=4096,   # sliding-window variant for long_500k
    source="arXiv:2402.19173",
)
