"""Yi-6B — dense llama-arch, GQA (32H/4KV). [arXiv:2403.04652]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    max_seq_len=4096,
    attention="gqa",
    rope_theta=5e6,
    activation="silu",
    long_context_window=4096,
    source="arXiv:2403.04652",
)
