"""DeepSeek-V2-236B — MLA (kv_lora 512) + MoE 160 routed top-6 + 2 shared.
[arXiv:2405.04434]"""
from repro.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,           # MLA: all heads share the latent cache
    d_ff=1536,                  # routed expert width
    vocab_size=102400,
    max_seq_len=131072,
    attention="mla",
    rope_theta=1e4,
    activation="silu",
    moe=MoEConfig(num_experts=160, experts_per_token=6, num_shared_experts=2,
                  expert_d_ff=1536),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    long_context_window=4096,
    source="arXiv:2405.04434",
)
