"""Paper model: 2-layer DNN with hidden size 100 for MNIST (Sec. VI-A)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mnist_dnn",
    family="small",
    num_layers=2,
    d_model=100,                # hidden width
    vocab_size=10,              # classes
    dtype="float32",
    source="paper Sec. VI-A (MNIST)",
)
