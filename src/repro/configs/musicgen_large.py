"""MusicGen-Large — decoder-only over EnCodec tokens (codec stubbed).
[arXiv:2306.05284]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,            # full MHA
    d_ff=8192,
    vocab_size=2048,            # per-codebook EnCodec codebook size
    max_seq_len=32768,
    attention="gqa",
    activation="gelu",
    num_audio_codebooks=4,
    long_context_window=4096,
    source="arXiv:2306.05284",
)
