"""RecurrentGemma-2B — RG-LRU + local attention (2 recurrent : 1 attn).
[arXiv:2402.19427]"""
from repro.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,             # MQA for the local-attention blocks
    d_ff=7680,
    vocab_size=256000,
    max_seq_len=1048576,        # unbounded in principle (fixed-size state)
    attention="gqa",
    rope_theta=1e4,
    activation="gelu",
    hybrid=HybridConfig(lru_width=2560, attention_window=2048,
                        pattern=("rglru", "rglru", "attn")),
    source="arXiv:2402.19427",
)
