"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                     # attention-free, no separate FFN (Mamba block only)
    vocab_size=50280,
    max_seq_len=1048576,
    attention="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4),
    source="arXiv:2405.21060",
)
