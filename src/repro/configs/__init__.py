"""Config registry: ``--arch <id>`` → ModelConfig, plus the 4 input shapes."""
from __future__ import annotations

import importlib

from repro.config import ModelConfig, ShapeConfig

ARCH_IDS = (
    "starcoder2_15b",
    "mixtral_8x22b",
    "deepseek_67b",
    "mamba2_370m",
    "musicgen_large",
    "llama32_vision_11b",
    "deepseek_v2_236b",
    "nemotron4_15b",
    "yi_6b",
    "recurrentgemma_2b",
    # the paper's own models
    "mnist_dnn",
    "lenet5",
    "char_lstm",
)

# canonical hyphenated ids from the assignment → module names
ALIASES = {
    "starcoder2-15b": "starcoder2_15b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-67b": "deepseek_67b",
    "mamba2-370m": "mamba2_370m",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "nemotron-4-15b": "nemotron4_15b",
    "yi-6b": "yi_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256,
                            kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32,
                               kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128,
                              kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1,
                             kind="decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    if mod_name not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCH_IDS)} "
                         f"(aliases: {sorted(ALIASES)})")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
