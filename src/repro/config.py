"""Configuration system.

Every experiment is described by a tree of frozen dataclasses:

  ``ExperimentConfig``
    ├── ``ModelConfig``     — architecture hyperparameters (family-dispatch)
    ├── ``FLConfig``        — PerFedS² / FL hyperparameters (A, S, n_ues, α, β, ...)
    ├── ``WirelessConfig``  — mobile-edge channel parameters (Table I of the paper)
    ├── ``ObsConfig``       — telemetry / tracing / reporting (src/repro/obs)
    ├── ``TrainConfig``     — optimizer / batching / steps
    └── ``MeshConfig``      — device mesh + sharding knobs

``src/repro/configs/<arch>.py`` files build ``ModelConfig`` instances for the ten
assigned architectures; ``configs/shapes.py`` defines the four assigned input
shapes.  CLI overrides are dotted ``key=value`` pairs parsed by ``apply_overrides``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (used when ModelConfig.family == 'moe')."""
    num_experts: int = 8
    experts_per_token: int = 2
    num_shared_experts: int = 0          # DeepSeek-V2 style shared experts
    expert_d_ff: int = 0                 # per-expert FFN width (0 → use d_ff)
    router_aux_loss_coef: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25        # expert capacity for dropless=False paths


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 → full-rank queries
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) sub-config."""
    state_dim: int = 128                 # N — SSM state size
    head_dim: int = 64                   # P — channels per SSD head
    num_heads: int = 0                   # 0 → derived as d_inner // head_dim
    expand: int = 2                      # d_inner = expand * d_model
    chunk_size: int = 256                # SSD chunk length
    conv_width: int = 4                  # depthwise conv kernel


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid (RG-LRU + local attention)."""
    lru_width: int = 0                   # 0 → d_model
    attention_window: int = 2048
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")   # 1:2 attn:recurrent


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` dispatches the model builder:
      dense  — decoder-only transformer (GQA/RoPE; covers llama-style, sq-relu, SWA)
      moe    — dense skeleton + MoE FFN (mixtral / deepseek-v2 w/ MLA)
      ssm    — Mamba-2 SSD stack (attention-free)
      hybrid — RG-LRU + local attention interleave
      vlm    — dense text decoder + cross-attention image layers (frontend stubbed)
      audio  — dense decoder over codec-frame embeddings (frontend stubbed)
      small  — the paper's own models (mnist_dnn / lenet5 / char_lstm)
    """
    name: str = "unnamed"
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                    # 0 → d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 8192
    # --- attention flavour ---
    attention: str = "gqa"               # gqa | mla | none
    rope_theta: float = 10000.0
    sliding_window: int = 0              # 0 → full attention (SWA archs set this)
    long_context_window: int = 4096      # window used by the long_500k sliding variant
    cross_attn_every: int = 0            # vlm: insert cross-attn layer every N layers
    num_image_tokens: int = 0            # vlm: stubbed patch-embedding count
    num_audio_codebooks: int = 0         # audio: EnCodec codebooks (delay-interleaved)
    # --- FFN flavour ---
    activation: str = "silu"             # silu | gelu | sq_relu
    # --- norms / embeddings ---
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # --- numerics ---
    dtype: str = "bfloat16"              # activation/param dtype
    remat: bool = True                   # activation checkpointing per layer
    scan_layers: bool = True             # lax.scan over homogeneous layer stacks
    attn_impl: str = "xla"               # xla | pallas
    attn_cast_f32: bool = True           # baseline: materialise k/v in f32;
                                         # False = bf16 reads + f32 MXU accum
                                         # (§Perf lever — halves decode traffic)
    # --- citation for provenance ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def reduced(self, max_d_model: int = 256, num_layers: int = 2,
                max_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        d = min(self.d_model, max_d_model)
        if self.hybrid is not None:
            # keep ≥ one full (rec, rec, attn) group
            num_layers = max(num_layers, len(self.hybrid.pattern))
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        kw: dict = dict(
            num_layers=num_layers, d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=0, d_ff=min(self.d_ff, 4 * d) or 0,
            vocab_size=min(self.vocab_size, vocab), max_seq_len=512,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=64, remat=False,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, max_experts),
                experts_per_token=min(self.moe.experts_per_token, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=(min(self.moe.expert_d_ff, 2 * d)
                             if self.moe.expert_d_ff else 0),
                capacity_factor=max(self.moe.capacity_factor, 8.0),  # dropless
            )
        if self.mla is not None:
            kw["mla"] = replace(
                self.mla, kv_lora_rank=min(self.mla.kv_lora_rank, 64),
                qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
                q_lora_rank=0,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 32),
                head_dim=min(self.ssm.head_dim, 32), num_heads=0, chunk_size=32,
            )
        if self.hybrid is not None:
            kw["hybrid"] = replace(
                self.hybrid, lru_width=0,
                attention_window=min(self.hybrid.attention_window, 64),
            )
        if self.cross_attn_every:
            kw["cross_attn_every"] = min(self.cross_attn_every, 2)
            kw["num_image_tokens"] = min(self.num_image_tokens or 16, 16)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# FL / PerFedS² configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    """Paper hyperparameters (Table I + Alg. 1/2)."""
    algorithm: str = "perfed"            # fedavg | fedprox | perfed
    mode: str = "semi"                   # sync | semi | async
    n_ues: int = 20
    participants_per_round: int = 5      # A
    staleness_bound: int = 5             # S
    rounds: int = 100                    # K
    alpha: float = 0.03                  # inner (adaptation) lr
    alpha_spread: float = 0.0            # per-UE α_i ∈ α·[1/(1+s), 1+s]
    beta: float = 0.07                   # global step size
    local_batch_size: int = 32
    local_epochs: int = 1                # E for fedavg-style local work
    prox_mu: float = 0.1                 # FedProx proximal coefficient
    first_order: bool = False            # FO-MAML (drop Hessian term)
    pfedme_lambda: float = 15.0          # pFedMe Moreau-envelope strength [11]
    pfedme_steps: int = 5                # inner solver steps for θ̂(w)
    staleness_discount: float = 1.0      # λ^τ payload weighting (SAFA/FedSA
                                         # style, refs [20][21]); 1.0 = paper
    hessian_batch: int = 32              # |D_h|
    outer_batch: int = 32                # |D_o|
    inner_batch: int = 32                # |D_in|
    eta_mode: str = "equal"              # equal | distance
    seed: int = 0


@dataclass(frozen=True)
class WirelessConfig:
    """Table I of the paper."""
    total_bandwidth_hz: float = 1e6      # B = 1 MHz
    path_loss_exp: float = 3.8           # κ
    noise_dbm_per_hz: float = -174.0     # N0
    tx_power_w: float = 0.01             # p_i
    cell_radius_m: float = 200.0
    rayleigh_scale: float = 40.0         # paper's Rayleigh parameter
    grad_bits: float = 0.0               # Z: 0 → derived from model size
    bits_per_param: int = 32             # payload precision (16 = fp16 uploads)
    cpu_cycles_per_sample: float = 2e5   # c_i
    cpu_freq_hz: float = 1e9             # ϑ_i nominal (heterogeneity multiplies this)
    cpu_hetero: float = 4.0              # max/min CPU speed ratio across UEs
    # fading RNG stream for cycle pricing:
    #   legacy  — per-requeue [k, n] Rayleigh matrix off the main numpy
    #             stream, bitwise identical to the original per-UE loop
    #             (the parity-suite reference);
    #   counter — lane-indexed counter-based draws (splitmix64 → inverse
    #             Rayleigh CDF), O(k) per requeue independent of n.  Same
    #             marginal distribution, different bitstream — goldens for
    #             this stream are pinned separately.
    rng: str = "legacy"                  # legacy | counter


@dataclass(frozen=True)
class MobilityConfig:
    """Mobile multi-cell edge extension (``src/repro/mobility``).

    ``enabled=False`` keeps the original single-static-cell path untouched;
    the degenerate mobile configuration (speed 0, one cell, hierarchy off)
    reproduces it bitwise (pinned by ``tests/test_mobility.py``).
    """
    enabled: bool = False
    model: str = "random_waypoint"       # static | random_waypoint | gauss_markov
    speed_mps: float = 1.0               # mean UE speed; ≤ 0 → static
    pause_s: float = 0.0                 # random-waypoint pause at each waypoint
    gm_alpha: float = 0.85               # Gauss-Markov memory parameter
    step_s: float = 1.0                  # mobility integration step [simulated s]
    n_cells: int = 1                     # base stations (hex-ish layout)
    hierarchy: bool = False              # per-cell edge servers + cloud tier
    cloud_sync_every: int = 5            # cloud merge every N edge rounds
    cell_participants: int = 0           # per-cell A (0 → ceil(A / n_cells))
    # --- heterogeneous per-cell radio resources ------------------------
    # per-BS uplink budget [Hz]: () → every cell owns the full
    # wireless.total_bandwidth_hz (legacy); one value → broadcast to all
    # cells; else one entry per cell (macro/micro mixes)
    cell_bandwidth_hz: Tuple[float, ...] = ()
    association: str = "nearest"         # nearest | load_aware
    # load_aware: extra effective metres per unit of relative cell load
    # (members / fair share, budget-normalised) — hot cells shed UEs
    load_penalty_m: float = 50.0
    # association refresh strategy: "safe_radius" re-scores only UEs whose
    # displacement since their last score exceeds their handover margin
    # (bitwise-identical results, amortized O(n) per tick); "full" forces
    # the legacy [n, k] recompute every tick (exactness reference)
    reassoc: str = "safe_radius"


@dataclass(frozen=True)
class ScenarioConfig:
    """Open-world traffic/churn dynamics (``src/repro/fl/scenario.py``).

    ``enabled=False`` (default) keeps the closed-world simulator: an
    immortal, stationary UE population, bitwise identical to every
    pre-scenario golden.  ``enabled=True`` turns the UE pool open: a
    Poisson arrival process activates dormant UEs mid-run (they are
    priced and queued like any other cycle), a per-UE departure hazard
    deactivates them (their in-flight upload cancels through the
    driver's epoch mechanism), the arrival intensity can carry a diurnal
    wave and a flash-crowd window (which also retargets a fraction of
    random-waypoint UEs at a hotspot cell), and each UE's label
    distribution can drift over simulated time.

    All scenario randomness comes from one auxiliary stream seeded by
    ``(sim seed, scenario seed)`` — enabling a scenario never perturbs
    the fading / mobility / payload RNG schedules.
    """
    enabled: bool = False
    # --- population ----------------------------------------------------
    # fraction of the UE pool active at t=0 (the rest is the dormant
    # pool Poisson arrivals draw from; always at least one UE active)
    initial_active_frac: float = 1.0
    # --- Poisson churn -------------------------------------------------
    arrival_rate: float = 0.0        # expected UE joins per simulated second
    departure_rate: float = 0.0      # per-active-UE departure hazard [1/s]
    min_active: int = 1              # departures never go below this
    horizon_s: float = 0.0           # no churn events after this (0 → unbounded)
    # --- diurnal load wave: λ(t) *= 1 + amp·sin(2π t / period) ---------
    diurnal_amplitude: float = 0.0   # in [0, 1]
    diurnal_period_s: float = 0.0    # 0 → no wave
    # --- flash crowd ---------------------------------------------------
    flash_time_s: float = -1.0       # window start (< 0 → no flash)
    flash_duration_s: float = 0.0
    flash_arrival_boost: float = 1.0  # λ multiplier inside the window
    flash_hotspot_cell: int = 0      # BS whose vicinity is the hotspot
    # fraction of active random-waypoint UEs retargeted at the hotspot
    flash_hotspot_frac: float = 0.0
    # --- non-stationary label drift ------------------------------------
    drift_rate: float = 0.0          # per-active-UE drift hazard [1/s]
    drift_frac: float = 0.3          # fraction of samples remapped per event
    # --- protocol under churn ------------------------------------------
    # clamp each cell's effective round size A to its live membership so
    # a cell that shrinks below A keeps closing (smaller) rounds instead
    # of live-locking; False reproduces the frozen-A legacy behaviour
    # (the stall is then surfaced via SimResult.aborted_rounds)
    adaptive_cell_a: bool = True
    seed: int = 0                    # scenario stream (auxiliary)


@dataclass(frozen=True)
class ObsConfig:
    """Observability (``src/repro/obs``): tracing, telemetry, reporting.

    Everything here is read-only instrumentation — enabling it never
    changes a trajectory (goldens are pinned with tracing fully on).
    ``run_simulation``'s ``tracer``/``trace_dir``/``reporter`` kwargs
    override these per call.
    """
    # progress reporting level: quiet | progress | debug.  The legacy
    # ``verbose=True`` kwarg maps to "progress" (same output, same text)
    report: str = "quiet"
    trace: bool = False                  # collect phase spans + counters
    trace_dir: str = ""                  # per-round JSONL (implies trace)
    # block on every engine dispatch / protocol feed and attribute the
    # time as device seconds (host = wall − device); synchronizes, so
    # leave off when measuring end-to-end throughput
    device_timing: bool = False
    profile_dir: str = ""                # jax.profiler trace → TensorBoard


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adam"              # server-side optimizer for at-scale path
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    seq_len: int = 4096
    global_batch_size: int = 256
    microbatch: int = 0                  # 0 → no gradient accumulation
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data_axis: int = 16
    model_axis: int = 16
    pods: int = 2
    # sharding strategy knobs (perf-iteration levers)
    shard_params_over_data: bool = True   # ZeRO-3 / FSDP-style 2-D param sharding
    shard_moe_experts: bool = True        # experts → model axis
    decode_cache_axis: str = "auto"       # auto | batch | sequence

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.multi_pod:
            return (self.pods, self.data_axis, self.model_axis)
        return (self.data_axis, self.model_axis)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"                  # train | prefill | decode


@dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    fl: FLConfig = field(default_factory=FLConfig)
    wireless: WirelessConfig = field(default_factory=WirelessConfig)
    mobility: MobilityConfig = field(default_factory=MobilityConfig)
    scenario: ScenarioConfig = field(default_factory=ScenarioConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)


# ---------------------------------------------------------------------------
# CLI overrides
# ---------------------------------------------------------------------------

def apply_overrides(cfg: Any, overrides: dict[str, str]) -> Any:
    """Apply dotted-path string overrides to a dataclass tree.

    ``apply_overrides(cfg, {"fl.participants_per_round": "10"})``
    """
    for path, raw in overrides.items():
        parts = path.split(".")
        cfg = _set_path(cfg, parts, raw)
    return cfg


def _coerce(raw: str, old: Any) -> Any:
    if isinstance(old, bool):
        return raw.lower() in ("1", "true", "yes", "on")
    if isinstance(old, int):
        return int(raw)
    if isinstance(old, float):
        return float(raw)
    if isinstance(old, tuple):
        def elem(x: str) -> Any:
            x = x.strip()
            try:                         # numeric tuples (cell_bandwidth_hz)
                return float(x)
            except ValueError:           # string tuples (hybrid.pattern)
                return x
        return tuple(elem(x) for x in raw.split(",") if x.strip())
    return raw


def _set_path(node: Any, parts: list[str], raw: str) -> Any:
    key = parts[0]
    if not dataclasses.is_dataclass(node):
        raise TypeError(f"cannot descend into non-dataclass at {key!r}")
    old = getattr(node, key)
    if len(parts) == 1:
        return replace(node, **{key: _coerce(raw, old)})
    return replace(node, **{key: _set_path(old, parts[1:], raw)})


def parse_cli_overrides(argv: list[str]) -> dict[str, str]:
    """Parse trailing ``a.b=c`` tokens from argv."""
    out: dict[str, str] = {}
    for tok in argv:
        if "=" in tok:
            k, v = tok.split("=", 1)
            out[k] = v
    return out
