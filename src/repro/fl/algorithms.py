"""The paper's 9 evaluated algorithms = {FedAvg, FedProx, PerFed} × {SYN, S², ASY}.

Names follow the figures:  FedAvg-SYN, FedProx-SYN, PerFed-SYN, FedAvgS2,
FedProxS2, PerFedS2 (the paper's contribution), FedAvg-ASY, FedProx-ASY,
PerFed-ASY.
"""
from __future__ import annotations

from typing import Dict, Tuple

_MODES = {"SYN": "sync", "S2": "semi", "ASY": "async"}
_FAMILIES = {"FedAvg": "fedavg", "FedProx": "fedprox", "PerFed": "perfed"}

ALGORITHMS: Dict[str, Tuple[str, str]] = {}
for fam, algo in _FAMILIES.items():
    for suffix, mode in _MODES.items():
        name = f"{fam}S2" if suffix == "S2" else f"{fam}-{suffix}"
        ALGORITHMS[name] = (algo, mode)


def algorithm_name(algorithm: str, mode: str) -> str:
    fam = {v: k for k, v in _FAMILIES.items()}[algorithm]
    suffix = {v: k for k, v in _MODES.items()}[mode]
    return f"{fam}S2" if suffix == "S2" else f"{fam}-{suffix}"
