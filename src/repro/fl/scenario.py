"""Open-world churn / traffic scenario runtime (``cfg.scenario``).

The closed-world simulator assumes an immortal, stationary UE population.
``ScenarioRuntime`` relaxes that: it owns an *activity mask* over a fixed
UE universe of size n and a stream of timed lifecycle events the driver
interleaves with its upload heap —

* **joins** — a (possibly time-varying) Poisson process activates dormant
  UEs: λ(t) = ``arrival_rate`` · (1 + ``diurnal_amplitude`` ·
  sin(2π t / ``diurnal_period_s``)) · flash boost, sampled exactly by
  Lewis–Shedler thinning.  The driver prices the joining UE a fresh cycle
  and hands it the current model.
* **departures** — each active UE carries an exponential departure hazard
  (aggregate rate ``departure_rate`` · n_active, memoryless, re-armed on
  every membership change); the driver cancels the leaver's in-flight
  upload through its epoch mechanism.
* **flash crowd** — a one-shot window start event (the driver boosts
  nothing itself: the arrival intensity already folds the boost in; the
  event retargets a fraction of random-waypoint UEs at the hotspot BS).
* **label drift** — each active UE carries a drift hazard; firing remaps
  a fraction of that client's labels (``ClientDataset.drift_labels``).

All randomness draws from ONE auxiliary generator seeded by
``(sim seed, scenario seed, stream tag)`` — the fading, mobility, and
payload RNG schedules are untouched, which is what lets a zero-rate
enabled scenario stay bitwise identical to the closed-world goldens.

Alive-time integration: the runtime tracks per-UE alive intervals so the
driver's ``wait_fraction`` can divide busy time by seconds of *existence*
rather than ``n · t`` (which charges departed UEs their whole absence as
idle).  With no churn events the total is exactly ``n · t``.
"""
from __future__ import annotations

# simlint: disable-file=SIM103,SIM104 -- dedicated auxiliary host-RNG
# stream seeded from (sim seed, scenario seed, stream tag); its draw
# schedule is event-driven by design (thinning / memoryless re-arms) and
# deliberately decoupled from the simulator's pinned schedules

from typing import List, Optional, Tuple

import numpy as np

from repro.config import ScenarioConfig

__all__ = ["ScenarioRuntime", "make_scenario"]

_SCEN_STREAM = 0x7363656E     # "scen" — decorrelates the scenario stream
_INF = float("inf")

# event kinds the driver switches on
JOIN, LEAVE, DRIFT, FLASH = "join", "leave", "drift", "flash"


class ScenarioRuntime:
    """Timed open-world events over a fixed UE universe (see module doc)."""

    def __init__(self, cfg: ScenarioConfig, n: int, *, seed: int = 0):
        if not 0.0 <= cfg.diurnal_amplitude <= 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1], got "
                             f"{cfg.diurnal_amplitude}")
        if cfg.flash_arrival_boost < 0.0:
            raise ValueError("flash_arrival_boost must be >= 0")
        self.cfg = cfg
        self.n = n
        # independent auxiliary stream: scenario draws never perturb the
        # simulator's fading / mobility / payload schedules
        self.rng = np.random.default_rng([seed, cfg.seed, _SCEN_STREAM])

        k = max(1, min(n, int(round(cfg.initial_active_frac * n))))
        self.active = np.zeros(n, dtype=bool)
        if k == n:
            self.active[:] = True
        else:
            self.active[np.sort(self.rng.choice(n, size=k,
                                                replace=False))] = True
        # alive-time integration (wait_fraction denominator)
        self.alive_s = np.zeros(n)
        self.alive_since = np.where(self.active, 0.0, np.nan)

        self.ue_joins = 0
        self.ue_departures = 0
        self.label_drifts = 0
        self.log: List[Tuple[float, str, int]] = []   # (t, kind, ue)

        self._t = 0.0                 # time of the last processed event
        self._arr_at = self._gen_arrival(0.0)
        self._dep_at = self._gen_exp(0.0, cfg.departure_rate)
        self._drift_at = self._gen_exp(0.0, cfg.drift_rate)
        self._flash_at = cfg.flash_time_s if cfg.flash_time_s >= 0.0 \
            else _INF

    # ------------------------------------------------------------------
    # intensity model
    # ------------------------------------------------------------------
    def _in_flash(self, t: float) -> bool:
        c = self.cfg
        return (c.flash_time_s >= 0.0
                and c.flash_time_s <= t < c.flash_time_s
                + c.flash_duration_s)

    def arrival_intensity(self, t: float) -> float:
        """λ(t): base rate × diurnal wave × flash boost [joins/s]."""
        c = self.cfg
        lam = c.arrival_rate
        if c.diurnal_amplitude > 0.0 and c.diurnal_period_s > 0.0:
            lam *= 1.0 + c.diurnal_amplitude * np.sin(
                2.0 * np.pi * t / c.diurnal_period_s)
        if self._in_flash(t):
            lam *= c.flash_arrival_boost
        return float(lam)

    def _past_horizon(self, t: float) -> bool:
        return self.cfg.horizon_s > 0.0 and t > self.cfg.horizon_s

    def _gen_arrival(self, t0: float) -> float:
        """Next accepted arrival after ``t0`` by Lewis–Shedler thinning
        against the envelope λ_max = rate·(1+amp)·max(boost, 1)."""
        c = self.cfg
        lam_max = c.arrival_rate * (1.0 + c.diurnal_amplitude) \
            * max(c.flash_arrival_boost, 1.0)
        if lam_max <= 0.0:
            return _INF
        t = t0
        while True:
            t += self.rng.exponential(1.0 / lam_max)
            if self._past_horizon(t):
                return _INF
            if self.rng.random() * lam_max <= self.arrival_intensity(t):
                return t

    def _gen_exp(self, t0: float, per_ue_rate: float) -> float:
        """Next event of an aggregate exponential clock (rate scales with
        the live population; memoryless, so re-arming on membership
        change is exact)."""
        rate = per_ue_rate * int(self.active.sum())
        if rate <= 0.0:
            return _INF
        t = t0 + self.rng.exponential(1.0 / rate)
        return _INF if self._past_horizon(t) else t

    def _rearm(self, t: float) -> None:
        """Membership changed at ``t``: re-draw the population-scaled
        clocks (exponentials are memoryless — this is distributionally
        exact, not an approximation)."""
        self._dep_at = self._gen_exp(t, self.cfg.departure_rate)
        self._drift_at = self._gen_exp(t, self.cfg.drift_rate)

    # ------------------------------------------------------------------
    # event interface (driver side)
    # ------------------------------------------------------------------
    def next_time(self) -> float:
        """Time of the next scheduled scenario event (inf when none)."""
        return min(self._arr_at, self._dep_at, self._drift_at,
                   self._flash_at)

    def can_spawn(self) -> bool:
        """Whether a future join can still create upload events — the
        only scenario event kind that feeds the driver's heap.  When the
        heap is dry and this is False the run is over: departures/drift
        alone can never restart progress.  A full pool still spawns if a
        departure can free a slot first."""
        if self._arr_at >= _INF:
            return False
        if not bool(self.active.all()):
            return True
        # full pool: a join needs a departure to free a slot first, which
        # the min_active floor must permit
        return self._dep_at < _INF and self.n > max(self.cfg.min_active, 1)

    def next_event(self, t_limit: float
                   ) -> Optional[Tuple[float, str, int]]:
        """Pop and apply the next *actionable* event at or before
        ``t_limit``; returns ``(t, kind, ue)`` (ue = −1 for flash) or
        ``None``.  Non-actionable firings (a join with no dormant UE
        left, a departure at the ``min_active`` floor) are consumed
        silently — their stream still advances."""
        while True:
            t = self.next_time()
            if t > t_limit:
                return None
            if t == self._arr_at:
                self._arr_at = self._gen_arrival(t)
                ue = self._pick(~self.active)
                if ue < 0:
                    continue                      # nobody left to join
                self._join(ue, t)
                return (t, JOIN, ue)
            if t == self._dep_at:
                if int(self.active.sum()) <= max(self.cfg.min_active, 1):
                    self._dep_at = self._gen_exp(
                        t, self.cfg.departure_rate)
                    continue                      # at the population floor
                ue = self._pick(self.active)
                self._leave(ue, t)
                return (t, LEAVE, ue)
            if t == self._drift_at:
                self._drift_at = self._gen_exp(t, self.cfg.drift_rate)
                ue = self._pick(self.active)
                if ue < 0:
                    continue
                self.label_drifts += 1
                self.log.append((t, DRIFT, ue))
                return (t, DRIFT, ue)
            # flash window start (one-shot)
            self._flash_at = _INF
            self.log.append((t, FLASH, -1))
            return (t, FLASH, -1)

    def _pick(self, mask: np.ndarray) -> int:
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            return -1
        return int(idx[self.rng.integers(len(idx))])

    def _join(self, ue: int, t: float) -> None:
        self.active[ue] = True
        self.alive_since[ue] = t
        self.ue_joins += 1
        self.log.append((t, JOIN, ue))
        self._rearm(t)

    def _leave(self, ue: int, t: float) -> None:
        self.active[ue] = False
        self.alive_s[ue] += t - self.alive_since[ue]
        self.alive_since[ue] = np.nan
        self.ue_departures += 1
        self.log.append((t, LEAVE, ue))
        self._rearm(t)

    # ------------------------------------------------------------------
    # flash-crowd hotspot targets
    # ------------------------------------------------------------------
    def hotspot_targets(self) -> np.ndarray:
        """Active UEs to retarget at the hotspot BS when the flash window
        opens (``flash_hotspot_frac`` of the live population)."""
        idx = np.nonzero(self.active)[0]
        k = int(round(self.cfg.flash_hotspot_frac * len(idx)))
        if k <= 0 or len(idx) == 0:
            return np.empty(0, dtype=np.int64)
        return np.sort(self.rng.choice(idx, size=min(k, len(idx)),
                                       replace=False))

    # ------------------------------------------------------------------
    # alive-time integration
    # ------------------------------------------------------------------
    def alive_total(self, t: float) -> float:
        """Σ_i seconds UE i existed in [0, t].  With zero churn this is
        exactly ``n · t`` (so the churn-free ``wait_fraction`` stays
        bitwise identical to the legacy denominator)."""
        open_s = float(self.active.sum()) * t \
            - float(np.nansum(np.where(self.active, self.alive_since, 0.0)))
        return float(self.alive_s.sum()) + open_s

    def was_alive(self, ue: int, t: float) -> bool:
        """Test support: was ``ue`` active at time ``t``?  Replays the
        event log from the UE's t=0 state, so it stays correct however
        many joins/leaves the UE has been through."""
        alive = self._initially_active(ue)
        for (te, kind, u) in self.log:
            if te > t:
                break
            if u != ue:
                continue
            if kind == JOIN:
                alive = True
            elif kind == LEAVE:
                alive = False
        return alive

    def _initially_active(self, ue: int) -> bool:
        """Reconstruct the t=0 activity bit by unwinding the UE's logged
        join/leave events from its current state."""
        alive = bool(self.active[ue])
        for (_te, kind, u) in reversed(self.log):
            if u != ue:
                continue
            if kind == JOIN:
                alive = False        # before the join it was dormant
            elif kind == LEAVE:
                alive = True
        return alive


def make_scenario(cfg: ScenarioConfig, n: int,
                  seed: int) -> Optional[ScenarioRuntime]:
    """The driver's entry point: a runtime when the scenario is enabled,
    else ``None`` (closed world, zero overhead)."""
    if not cfg.enabled:
        return None
    return ScenarioRuntime(cfg, n, seed=seed)
