"""Mobile multi-cell simulation driver (``cfg.mobility.enabled=True``).

The same event-driven PerFedS² loop as ``fl/simulation.py``, generalised to
a ``MultiCellNetwork``:

* UE positions advance under a vectorized mobility model as simulated time
  passes, so path loss — and therefore upload times and the straggler
  population — is *time-varying*.
* Each UE associates with the nearest BS; handovers re-home it to the new
  cell's scheduler and bandwidth budget (cells whose membership changed are
  re-allocated lazily, at the next cycle start that needs them).
* With ``mobility.hierarchy`` on, each cell runs its own semi-synchronous
  edge server (Eq. 8 via the engine's fused ``stale_aggregate_tree`` path)
  and a cloud tier merges cell models every ``cloud_sync_every`` edge
  rounds (``core/hierarchy.py``).

Batching: arrivals are drained in time order until the first server (cell)
would close its round — none of those events can be affected by a
distribution, so their payloads are computable as one engine batch, exactly
the invariant the single-cell driver exploits.  When the whole drain
belongs to one cell and matches its ``A``, the engine's fused
one-dispatch-per-version-group ``round_update`` path is taken verbatim.

Degenerate configuration (speed 0, one cell, hierarchy off) reproduces the
static single-cell driver **bitwise** for the same seed: the network
consumes the main RNG stream in the legacy order, the drain yields the
identical batches, and all engine calls receive identical inputs
(pinned by ``tests/test_mobility.py``).
"""
from __future__ import annotations

import heapq
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.config import ExperimentConfig
from repro.core.bandwidth import weighted_equal_rate_allocation
from repro.core.hierarchy import HierarchicalServer, HierarchyConfig
from repro.core.scheduler import get_policy
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data.partition import ClientDataset
from repro.fl.engine import SimulationEngine, ensure_engine
from repro.fl.simulation import SimResult
from repro.mobility.multicell import MultiCellNetwork
from repro.wireless.timing import compute_time, upload_time, model_bits


def run_mobile_simulation(cfg: ExperimentConfig, model,
                          clients: List[ClientDataset], *,
                          algorithm: str = "perfed", mode: str = "semi",
                          bandwidth_policy: str = "optimal",
                          max_rounds: Optional[int] = None,
                          eval_every: int = 5, eval_clients: int = 8,
                          seed: int = 0, name: Optional[str] = None,
                          verbose: bool = False,
                          payload_mode: Optional[str] = None,
                          engine: Optional[SimulationEngine] = None
                          ) -> SimResult:
    fl, mob, wl = cfg.fl, cfg.mobility, cfg.wireless
    n = len(clients)
    max_rounds = max_rounds or fl.rounds
    rng = np.random.default_rng(seed)
    init_key, payload_key, eval_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)

    # --- network + η -------------------------------------------------------
    policy = get_policy(fl.eta_mode)
    net = MultiCellNetwork.drop(
        wl, n, n_cells=mob.n_cells, seed=seed, mobility=mob.model,
        speed_mps=mob.speed_mps, pause_s=mob.pause_s, gm_alpha=mob.gm_alpha,
        uniform_distance=policy.uniform_drop, step_s=mob.step_s)
    eta = policy.frequencies(n, net)
    h_mean = wl.rayleigh_scale * float(np.sqrt(np.pi / 2))

    # --- per-cell bandwidth (re-allocated lazily on membership change) -----
    if bandwidth_policy not in ("optimal", "equal"):
        raise ValueError(f"unknown bandwidth policy {bandwidth_policy!r}")
    bw = np.zeros(n)
    dirty_cells: set = set()

    def realloc(c: int) -> None:
        members = net.cell_members(c)
        if len(members) == 0:
            return
        if bandwidth_policy == "optimal":
            chans = [net.channel(i, h_mean) for i in members]
            bw[members] = weighted_equal_rate_allocation(
                eta[members], chans, wl.total_bandwidth_hz)
        else:
            bw[members] = wl.total_bandwidth_hz / len(members)

    for c in range(net.n_cells):
        realloc(c)

    # --- model / engine ----------------------------------------------------
    params0 = model.init(init_key)
    z_bits = wl.grad_bits or model_bits(params0, wl.bits_per_param)
    engine = ensure_engine(engine, model, fl, algorithm=algorithm,
                           payload_mode=payload_mode)
    disp0, pay0 = engine.dispatches, engine.payloads_computed

    if fl.alpha_spread > 0:
        s = 1.0 + fl.alpha_spread
        alphas = fl.alpha * np.exp(rng.uniform(-np.log(s), np.log(s), size=n))
    else:
        alphas = np.full(n, fl.alpha)

    # --- servers -----------------------------------------------------------
    hier: Optional[HierarchicalServer] = None
    server: Optional[SemiSyncServer] = None
    if mob.hierarchy and mob.n_cells > 1:
        if mode != "semi":
            raise ValueError("hierarchical aggregation runs semi-sync edge "
                             f"servers; mode={mode!r} is not supported")
        a_req = mob.cell_participants or max(
            1, -(-fl.participants_per_round // mob.n_cells))
        members0 = [net.cell_members(c) for c in range(mob.n_cells)]
        # cap each cell's A at its initial population: a cell holding fewer
        # members than A could never close a round and would starve its UEs
        cell_cfgs = [ServerConfig(
            n_ues=n, participants_per_round=max(1, min(a_req, max(len(m),
                                                                  1))),
            staleness_bound=fl.staleness_bound, beta=fl.beta, mode="semi",
            staleness_discount=fl.staleness_discount)
            for m in members0]
        hier = HierarchicalServer(
            params0, cell_cfgs,
            HierarchyConfig(n_cells=mob.n_cells,
                            cloud_sync_every=mob.cloud_sync_every),
            members0)
    else:
        server = SemiSyncServer(params0, ServerConfig(
            n_ues=n, participants_per_round=fl.participants_per_round,
            staleness_bound=fl.staleness_bound, beta=fl.beta, mode=mode,
            staleness_discount=fl.staleness_discount))

    def rounds_done() -> int:
        return hier.edge_rounds if hier is not None else server.round

    # --- per-UE state ------------------------------------------------------
    held_params: List[Any] = [params0 for _ in range(n)]
    d_i = np.array([min(fl.inner_batch + fl.outer_batch + fl.hessian_batch,
                        len(c)) for c in clients])
    busy_time = np.zeros(n)
    batch_sig = [c.triplet_sizes(fl.inner_batch, fl.outer_batch,
                                 fl.hessian_batch) for c in clients]

    def cycle_duration(i: int) -> float:
        c = int(net.assoc[i])
        if c in dirty_cells:
            realloc(c)
            dirty_cells.discard(c)
        h = float(net.sample_fading()[i])
        tcmp = compute_time(wl.cpu_cycles_per_sample, int(d_i[i]),
                            float(net.cpu_freq[i]))
        tcom = upload_time(z_bits, float(bw[i]), net.channel(i, h))
        return tcmp + tcom

    # --- eval --------------------------------------------------------------
    eval_idx = rng.choice(n, size=min(eval_clients, n), replace=False)

    def evaluate(params, k: int) -> Tuple[float, float, float]:
        r = jax.random.fold_in(eval_key, k)
        pl, gl, ac = [], [], []
        for ci in eval_idx:
            c = clients[ci]
            r, sub = jax.random.split(r)
            batches = {"inner": c.sample(fl.inner_batch),
                       "outer": {k2: v for k2, v in c.test.items()}}
            p, g, a = engine.eval_one(params, batches, sub)
            pl.append(float(p)); gl.append(float(g)); ac.append(float(a))
        acc = (float(np.nanmean(ac))
               if np.any(np.isfinite(ac)) else float("nan"))
        return float(np.mean(pl)), float(np.mean(gl)), acc

    # --- event loop --------------------------------------------------------
    heap: List[Tuple[float, int, int, int, float, int]] = []
    epoch = np.zeros(n, dtype=np.int64)
    seq = 0
    for i in range(n):
        dur = cycle_duration(i)
        heapq.heappush(heap, (dur, seq, i, 0, dur, 0))
        seq += 1

    times, plosses, glosses, accs, rounds_at = [], [], [], [], []
    t_now = 0.0
    do_eval = eval_every > 0

    if do_eval:
        p0, g0, a0 = evaluate(params0, 0)
        times.append(0.0); plosses.append(p0); glosses.append(g0)
        accs.append(a0); rounds_at.append(0)

    def handle(result) -> None:
        nonlocal seq
        for i in result["distribute"]:
            held_params[i] = result["params"]
            epoch[i] += 1           # cancels any in-flight computation
            dur_i = cycle_duration(i)
            heapq.heappush(heap, (t_now + dur_i, seq, i, result["round"],
                                  dur_i, int(epoch[i])))
            seq += 1
        k = result["round"]
        if do_eval and (k % eval_every == 0 or k == max_rounds):
            p, g, a = evaluate(result["params"], k)
            times.append(t_now); plosses.append(p); glosses.append(g)
            accs.append(a); rounds_at.append(k)
            if verbose:
                cell = f" cell={result['cell']}" if "cell" in result else ""
                print(f"[{name or algorithm}-{mode}]{cell} round {k:4d} "
                      f"t={t_now:8.2f}s ploss={p:.4f} gloss={g:.4f}")

    while rounds_done() < max_rounds and heap:
        # ---- drain arrivals until the first cell would close its round ----
        # No distribution (hence no cancellation, no membership effect on
        # queued events) can occur before then, so every drained payload is
        # computable NOW, as one batch — the same invariant the static
        # driver exploits, held per cell.
        if hier is not None:
            need = [hier.arrivals_until_round(c)
                    for c in range(mob.n_cells)]
        else:
            need = [server.arrivals_until_round()]
        drained = [0] * len(need)
        batch: List[Tuple[float, int, int, float, int]] = []
        closing: Optional[int] = None
        while heap:
            t, sq, ue, _version, dur, ev_epoch = heapq.heappop(heap)
            if ev_epoch != epoch[ue]:
                continue                # abandoned (stale-refresh) cycle
            for (u, src, dst) in net.advance_to(t):
                if hier is not None:
                    hier.handover(u, src, dst)
                dirty_cells.add(src)
                dirty_cells.add(dst)
            c = int(net.assoc[ue]) if hier is not None else 0
            batch.append((t, ue, sq, dur, c))
            drained[c] += 1
            if drained[c] >= need[c]:
                closing = c
                break
        if not batch:
            break

        held = [held_params[ue] for _, ue, _, _, _ in batch]
        triplets = [clients[ue].sample_triplet(fl.inner_batch, fl.outer_batch,
                                               fl.hessian_batch)
                    for _, ue, _, _, _ in batch]
        a_i = [alphas[ue] for _, ue, _, _, _ in batch]

        srv_a = (hier.cells[closing].a if hier is not None else server.a) \
            if closing is not None else -1
        if (engine.payload_mode == "batched" and len(batch) == srv_a
                and srv_a <= engine.max_bucket
                and all(b[4] == closing for b in batch)
                and len({batch_sig[ue] for _, ue, _, _, _ in batch}) == 1):
            # fused fast path: the whole round of the closing cell — one
            # device dispatch per model-version group
            for t, ue, _sq, dur, _c in batch:
                t_now = t
                busy_time[ue] += dur

            def aggregate(params, weights):
                return engine.round_update(
                    params, held, triplets,
                    [sq for _, _, sq, _, _ in batch],
                    a_i, weights, beta=fl.beta, base_key=payload_key)

            ues = [ue for _, ue, _, _, _ in batch]
            if hier is not None:
                handle(hier.on_round_batch(closing, ues, aggregate))
            else:
                handle(server.on_round_batch(ues, aggregate))
        else:
            payloads = engine.compute_payloads(
                held, triplets,
                [jax.random.fold_in(payload_key, sq)
                 for _, _, sq, _, _ in batch],
                a_i)
            for (t, ue, _sq, dur, c), payload in zip(batch, payloads):
                t_now = t
                busy_time[ue] += dur
                if hier is not None:
                    result = hier.on_arrival(c, ue, payload)
                else:
                    result = server.on_arrival(ue, payload)
                if result is not None:
                    handle(result)

    proto = hier if hier is not None else server
    jax.block_until_ready(jax.tree.leaves(proto.params))

    wait_frac = float(1.0 - busy_time.sum() / max(n * t_now, 1e-9))
    return SimResult(
        name=name or f"{algorithm}-{mode}",
        times=np.array(times), losses=np.array(plosses),
        global_losses=np.array(glosses), accs=np.array(accs),
        rounds=np.array(rounds_at), total_time=t_now,
        pi=proto.pi_matrix(), eta_target=eta,
        eta_realised=proto.realised_eta(),
        wait_fraction=max(wait_frac, 0.0),
        payload_dispatches=engine.dispatches - disp0,
        payloads_computed=engine.payloads_computed - pay0,
        n_cells=net.n_cells, handovers=net.handovers,
        cloud_rounds=hier.cloud_rounds if hier is not None else 0,
    )
