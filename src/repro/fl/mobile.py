"""Mobile multi-cell simulation driver (``cfg.mobility.enabled=True``).

The same event-driven PerFedS² loop as ``fl/simulation.py`` — literally:
both are thin configurations of ``fl.driver.run_event_loop``.  The
``MobileAdapter`` below contributes what mobility changes:

* UE positions advance under a vectorized mobility model as simulated time
  passes, so path loss — and therefore upload times and the straggler
  population — is *time-varying* (``advance_to``).
* Each UE associates under ``mobility.association`` (pure nearest-BS, or
  load-aware: distance plus a members-per-budget penalty so hot cells shed
  UEs); handovers re-home it to the new cell's scheduler and bandwidth
  budget (cells whose membership changed are re-allocated lazily, at the
  next requeue that touches them — ``pre_requeue``).
* Each cell owns its own uplink budget (``mobility.cell_bandwidth_hz``:
  macro/micro mixes; unset → every cell owns the full system bandwidth)
  and splits it per ``bandwidth_policy``: ``equal`` (even split over
  members), ``optimal`` (Theorem-4 weighted-equal-rate), or ``theorem2``
  (the paper's per-round equal-finish bisection over the cell's current
  members, warm-started from the cell's previous ``t_star`` — previously
  only the static path's benchmarks ran it).
* With ``mobility.hierarchy`` on, each cell runs its own semi-synchronous
  edge server (Eq. 8 via the engine's fused ``stale_aggregate_tree`` path)
  and a cloud tier merges cell models every ``cloud_sync_every`` edge
  rounds (``core/hierarchy.py``).

Arrival routing: heap events carry the cell that *dispatched* the cycle
(the UE's association at cycle start), and the driver routes each arrival
back to that cell.  An upload in flight across a handover therefore counts
toward — and closes — the round it was computed against, and
``HierarchicalServer``'s departed-UE bookkeeping (visiting staleness, no
membership resurrection) actually fires.  Routing by pop-time association,
as the pre-unification driver did, both mis-credited such uploads to the
destination cell and made the departed path dead code.

Degenerate configuration (speed 0, one cell, hierarchy off) reproduces the
static single-cell driver **bitwise** for the same seed: the network
consumes the main RNG stream in the legacy order, the drain yields the
identical batches, and all engine calls receive identical inputs
(pinned by ``tests/test_mobility.py`` and ``tests/test_driver.py``).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.config import ExperimentConfig
from repro.core.bandwidth import (equal_finish_allocation,
                                  weighted_equal_rate_allocation)
from repro.core.hierarchy import HierarchicalServer, HierarchyConfig
from repro.core.scheduler import get_policy
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data.partition import ClientDataset
from repro.fl.driver import SimResult, TopologyAdapter, run_event_loop
from repro.fl.engine import SimulationEngine
from repro.mobility.multicell import MultiCellNetwork
from repro.obs import trace as obs
from repro.wireless.channel import noise_w_per_hz, pathloss_pow
from repro.wireless.timing import compute_times

__all__ = ["SimResult", "MobileAdapter", "run_mobile_simulation"]


class MobileAdapter(TopologyAdapter):
    """Moving multi-cell topology + per-cell (or flat) semi-sync protocol."""

    def __init__(self, cfg: ExperimentConfig, n: int, *, seed: int,
                 bandwidth_policy: str, mode: str):
        fl, mob, wl = cfg.fl, cfg.mobility, cfg.wireless
        policy = get_policy(fl.eta_mode)
        self.net = MultiCellNetwork.drop(
            wl, n, n_cells=mob.n_cells, seed=seed, mobility=mob.model,
            speed_mps=mob.speed_mps, pause_s=mob.pause_s,
            gm_alpha=mob.gm_alpha, uniform_distance=policy.uniform_drop,
            step_s=mob.step_s, cell_bandwidth_hz=mob.cell_bandwidth_hz,
            association=mob.association, load_penalty_m=mob.load_penalty_m,
            reassoc=mob.reassoc)
        self.eta = policy.frequencies(n, self.net)
        self._h_mean = wl.rayleigh_scale * float(np.sqrt(np.pi / 2))

        if bandwidth_policy not in ("optimal", "equal", "theorem2"):
            raise ValueError(f"unknown bandwidth policy {bandwidth_policy!r}")
        self._bandwidth_policy = bandwidth_policy
        self._wl = wl
        # Theorem-2 link-budget inputs: bound by the driver via
        # bind_link_budget (Z depends on the model, which does not exist
        # yet); until then theorem2 cells fall back to an equal split of
        # their own budget — never actually priced, because binding marks
        # every cell dirty and pre_requeue runs before the first pricing
        self._z_bits: float = 0.0
        self._tcmp: Optional[np.ndarray] = None
        self._t_star = np.zeros(self.net.n_cells)   # warm-start per cell
        self.bw = np.zeros(n)
        self._dirty_cells: set = set()
        for c in range(self.net.n_cells):
            self._realloc(c)

        self._hier_on = mob.hierarchy and mob.n_cells > 1
        if self._hier_on and mode != "semi":
            raise ValueError("hierarchical aggregation runs semi-sync edge "
                             f"servers; mode={mode!r} is not supported")
        self.n_protocol_cells = mob.n_cells if self._hier_on else 1
        self._fl, self._mob, self._mode, self._n = fl, mob, mode, n
        self.hier: Optional[HierarchicalServer] = None
        self.server: Optional[SemiSyncServer] = None
        # open-world scenario state (inert when cfg.scenario is off):
        # adaptive per-cell A — clamp each cell's close threshold to live
        # membership so a shrunken cell keeps closing rounds (the fix for
        # the frozen-at-init-A live-lock)
        self._scen = cfg.scenario
        self._adaptive_a = cfg.scenario.enabled and cfg.scenario.adaptive_cell_a
        self._active_mask: Optional[np.ndarray] = None

    # --- per-cell bandwidth (re-allocated lazily on membership change) -
    def bind_link_budget(self, z_bits: float, d_i: np.ndarray) -> None:
        """Driver hook: receive Z and per-UE sample counts, then force a
        re-allocation of every cell so the theorem2 policy prices real
        link budgets from the very first cycle."""
        self._z_bits = float(z_bits)
        self._tcmp = compute_times(self._wl.cpu_cycles_per_sample, d_i,
                                   self.net.cpu_freq)
        if self._bandwidth_policy == "theorem2":
            self._dirty_cells.update(range(self.net.n_cells))

    def _realloc(self, c: int) -> None:
        members = self.net.cell_members(c)
        if len(members) == 0:
            # drop the theorem2 warm-start: the old membership's t_star is
            # meaningless once the cell empties, and a re-populated cell
            # must not seed its equal-finish bisection from it
            self._t_star[c] = 0.0
            return
        budget = float(self.net.cell_bw[c])
        if self._bandwidth_policy == "optimal":
            chans = [self.net.channel(i, self._h_mean) for i in members]
            self.bw[members] = weighted_equal_rate_allocation(
                self.eta[members], chans, budget)
        elif self._bandwidth_policy == "theorem2" and self._tcmp is not None:
            self._realloc_theorem2(c, members, budget)
        else:
            self.bw[members] = budget / len(members)

    def _realloc_theorem2(self, c: int, members: np.ndarray,
                          budget: float) -> None:
        """Theorem-2 equal-finish split of the cell's budget over its
        current members (mean-fading channel snapshot, true per-UE compute
        times), warm-started from the cell's previous ``t_star``.  A
        non-converged bisection is retried cold with a wider iteration
        budget; if it *still* reports non-convergence the cell falls back
        to an equal split rather than trusting an allocation that no
        longer equalises finish times (the ``converged`` contract of
        ``EqualFinishAllocation``).

        The SNR numerators go in directly as ``q`` — same values, to the
        bit, as building per-member ``UEChannel``s (``pathloss_pow`` keeps
        d^{−κ} on scalar pow exactly as ``UEChannel.q`` does), without the
        throwaway object list on every membership change."""
        wl = self._wl
        q = wl.tx_power_w * self._h_mean \
            * pathloss_pow(self.net.distances[members], wl.path_loss_exp) \
            / noise_w_per_hz(wl.noise_dbm_per_hz)
        z = np.full(len(members), self._z_bits)
        tc = self._tcmp[members]
        hint = float(self._t_star[c]) if self._t_star[c] > 0 else None
        res = equal_finish_allocation(z, tc, None, budget, t_hint=hint, q=q)
        if not res.converged:
            res = equal_finish_allocation(z, tc, None, budget, max_iter=400,
                                          q=q)
        if res.converged:
            self.bw[members] = res.b
            self._t_star[c] = res.t_star
        else:
            self.bw[members] = budget / len(members)
            self._t_star[c] = 0.0

    # --- protocol ------------------------------------------------------
    def make_servers(self, params0) -> None:
        fl, mob, n = self._fl, self._mob, self._n
        if self._hier_on:
            a_req = mob.cell_participants or max(
                1, -(-fl.participants_per_round // mob.n_cells))
            members0 = [self.net.cell_members(c) for c in range(mob.n_cells)]
            # Legacy behaviour: cap each cell's A at its *initial*
            # population, frozen for the whole run.  That prevents a
            # never-closable round at t=0, but handovers/churn can still
            # drop a cell below its frozen A later — it then starves its
            # members forever.  The adaptive mode keeps the nominal A and
            # clamps the effective close threshold to LIVE membership,
            # re-pushed before every drain (``pre_drain``).
            cell_cfgs = [ServerConfig(
                n_ues=n,
                participants_per_round=(
                    a_req if self._adaptive_a
                    else max(1, min(a_req, max(len(m), 1)))),
                staleness_bound=fl.staleness_bound, beta=fl.beta,
                mode="semi", staleness_discount=fl.staleness_discount)
                for m in members0]
            self.hier = HierarchicalServer(
                params0, cell_cfgs,
                HierarchyConfig(n_cells=mob.n_cells,
                                cloud_sync_every=mob.cloud_sync_every),
                members0)
            if self._adaptive_a:
                self.pre_drain()        # clamp before the first drain too
        else:
            self.server = SemiSyncServer(params0, ServerConfig(
                n_ues=n, participants_per_round=fl.participants_per_round,
                staleness_bound=fl.staleness_bound, beta=fl.beta,
                mode=self._mode, staleness_discount=fl.staleness_discount))
            if self._active_mask is not None:
                # dormant UEs must neither be distributed to nor appear
                # stale: deactivate them in the flat server
                self.server.ue_active[:] = self._active_mask
                if self._adaptive_a:
                    self.pre_drain()

    def rounds_done(self) -> int:
        return self.hier.edge_rounds if self.hier is not None \
            else self.server.round

    def need(self, cell: int) -> int:
        if self.hier is not None:
            return self.hier.arrivals_until_round(cell)
        return self.server.arrivals_until_round()

    def participants(self, cell: int) -> int:
        # the EFFECTIVE round size (== A unless live-cap clamped): the
        # fused-dispatch path batches exactly this many lanes
        return self.hier.cells[cell].target if self.hier is not None \
            else self.server.target

    def on_arrival(self, cell, ue, payload):
        if self.hier is not None:
            return self.hier.on_arrival(cell, ue, payload)
        return self.server.on_arrival(ue, payload)

    def on_arrival_batch(self, cells, ues, payloads):
        if self.hier is not None:
            return self.hier.on_arrival_batch(cells, ues, payloads)
        return self.server.on_arrival_batch(ues, payloads)

    def on_round_batch(self, cell, ues, aggregate_fn):
        if self.hier is not None:
            return self.hier.on_round_batch(cell, ues, aggregate_fn)
        return self.server.on_round_batch(ues, aggregate_fn)

    def protocol(self):
        return self.hier if self.hier is not None else self.server

    # --- topology ------------------------------------------------------
    def dispatch_cell(self, ue: int) -> int:
        # stamped on the heap event so the arrival routes back here even
        # if the UE hands over while the upload is in flight
        return int(self.net.assoc[ue]) if self.hier is not None else 0

    def dispatch_cells(self, ues) -> np.ndarray:
        ues = np.asarray(ues, dtype=np.int64)
        if self.hier is not None:
            return self.net.assoc[ues].astype(np.int64)
        return np.zeros(len(ues), dtype=np.int64)

    def advance_to(self, t: float) -> None:
        for (u, src, dst) in self.net.advance_to(t):
            if self.hier is not None:
                self.hier.handover(u, src, dst)
            self._dirty_cells.add(src)
            self._dirty_cells.add(dst)

    def pre_requeue(self, ues) -> None:
        # vectorized: the common warm-path case (no membership change
        # since the last pricing) exits on one set check instead of a
        # python loop over every requeued lane
        if not self._dirty_cells:
            return
        with obs.CURRENT.span("bandwidth"):
            touched = np.unique(
                self.net.assoc[np.asarray(ues, dtype=np.int64)])
            for c in touched:
                c = int(c)
                if c in self._dirty_cells:
                    self._realloc(c)
                    self._dirty_cells.discard(c)

    # --- open-world scenario hooks -------------------------------------
    def bind_active(self, mask: np.ndarray) -> None:
        # shared reference: the scenario runtime flips bits in place and
        # the network's membership queries see them immediately
        self._active_mask = mask
        self.net.active = mask

    def pre_drain(self) -> None:
        # cap = pending + in-flight: live members whose upload is already
        # held can't produce another arrival before the close, so they
        # are subtracted from the members that still can
        if not self._adaptive_a:
            return
        counts = self.net.cell_counts()
        if self.hier is not None:
            for c in range(self.net.n_cells):
                pend = self.hier.cells[c].pending_ue_set()
                members = self.net.cell_members(c)
                in_flight = int(sum(1 for u in members
                                    if int(u) not in pend))
                self.hier.set_live_cap(c, int(counts[c]), in_flight)
        elif self.server is not None:
            pend = self.server.pending_ue_set()
            live = int(counts.sum())
            live_pending = 0 if self._active_mask is None else \
                sum(1 for u in pend if self._active_mask[u])
            self.server.set_live_cap(live, live - live_pending)

    def flush_ready(self):
        if not self._adaptive_a:
            return []
        if self.hier is not None:
            out = []
            for c in range(self.net.n_cells):
                res = self.hier.flush(c)
                if res is not None:
                    out.append(res)
            return out
        res = self.server.flush()
        return [res] if res is not None else []

    def on_join(self, ue: int):
        cell = int(self.net.assoc[ue])
        self._dirty_cells.add(cell)     # bandwidth re-split with the joiner
        if self.hier is not None:
            self.hier.join(ue, cell)
            return self.hier.cells[cell].params
        self.server.activate(ue)
        return self.server.params

    def on_leave(self, ue: int) -> None:
        # net.active is the scenario's mask (already flipped); drop the
        # leaver from its cell's membership bookkeeping + bandwidth split
        self._dirty_cells.add(int(self.net.assoc[ue]))
        if self.hier is not None:
            self.hier.leave(ue)
        else:
            self.server.deactivate(ue)

    def on_flash(self, idx: np.ndarray, rng: np.random.Generator) -> int:
        hotspot = min(max(self._scen.flash_hotspot_cell, 0),
                      self.net.n_cells - 1)
        return self.net.retarget_waypoints(
            idx, hotspot, self._wl.cell_radius_m / 4.0, rng)

    def cell_membership(self):
        if self._active_mask is None:
            return None
        counts = self.net.cell_counts()
        if self.hier is not None:
            return [int(c) for c in counts]
        return [int(counts.sum())]

    def result_extras(self):
        return {
            "n_cells": self.net.n_cells,
            "handovers": self.net.handovers,
            "cloud_rounds":
                self.hier.cloud_rounds if self.hier is not None else 0,
            "departed_arrivals":
                self.hier.departed_arrivals if self.hier is not None else 0,
        }


def run_mobile_simulation(cfg: ExperimentConfig, model,
                          clients: List[ClientDataset], *,
                          algorithm: str = "perfed", mode: str = "semi",
                          bandwidth_policy: str = "optimal",
                          max_rounds: Optional[int] = None,
                          eval_every: int = 5, eval_clients: int = 8,
                          seed: int = 0, name: Optional[str] = None,
                          verbose: bool = False,
                          payload_mode: Optional[str] = None,
                          engine: Optional[SimulationEngine] = None,
                          **obs_kw) -> SimResult:
    """``obs_kw`` forwards the telemetry knobs (``tracer`` / ``trace_dir``
    / ``profile_dir`` / ``reporter``) to ``run_event_loop``."""
    adapter = MobileAdapter(cfg, len(clients), seed=seed,
                            bandwidth_policy=bandwidth_policy, mode=mode)
    return run_event_loop(cfg, model, clients, adapter,
                          algorithm=algorithm, mode=mode,
                          max_rounds=max_rounds, eval_every=eval_every,
                          eval_clients=eval_clients, seed=seed, name=name,
                          verbose=verbose, payload_mode=payload_mode,
                          engine=engine, **obs_kw)
