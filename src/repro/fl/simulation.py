"""Event-driven simulator of PerFedS² over a mobile edge network.

Combines all the pieces:

  wireless.EdgeNetwork   — geometry, Rayleigh fading, heterogeneous CPUs
  core.bandwidth         — Theorem-2/4 allocations (or equal-split baseline)
  core.scheduler         — η targets (equal / distance-derived)
  core.server            — Algorithm 1 round protocol (sync / semi / async)
  fl.client              — payload math (fedavg / fedprox / perfed)

The event loop is a priority queue over UE upload-finish times.  Each UE
holds the last model version it received; payloads are computed against that
version (⇒ real gradient staleness, exactly as in the paper).  Wall-clock
time uses Eq. (10)–(12) with fading resampled per local iteration.
"""
from __future__ import annotations

import heapq
import time as pytime
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ExperimentConfig
from repro.core.bandwidth import weighted_equal_rate_allocation, uplink_rate
from repro.core.scheduler import relative_frequencies
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data.partition import ClientDataset
from repro.fl.client import make_payload_fn, personalized_eval
from repro.wireless.channel import EdgeNetwork
from repro.wireless.timing import compute_time, upload_time, model_bits


@dataclass
class SimResult:
    name: str
    times: np.ndarray            # wall-clock at each eval point [s]
    losses: np.ndarray           # personalized (PFL) eval loss
    global_losses: np.ndarray    # loss of the raw global model
    accs: np.ndarray             # accuracy if the task defines one (else nan)
    rounds: np.ndarray           # round index at each eval point
    total_time: float
    pi: np.ndarray               # realised schedule matrix
    eta_target: np.ndarray
    eta_realised: np.ndarray
    wait_fraction: float         # mean fraction of time UEs spent idle


def run_simulation(cfg: ExperimentConfig, model, clients: List[ClientDataset],
                   *, algorithm: str = "perfed", mode: str = "semi",
                   bandwidth_policy: str = "optimal",
                   max_rounds: Optional[int] = None,
                   eval_every: int = 5, eval_clients: int = 8,
                   seed: int = 0, name: Optional[str] = None,
                   verbose: bool = False) -> SimResult:
    fl = cfg.fl
    n = len(clients)
    max_rounds = max_rounds or fl.rounds
    rng = np.random.default_rng(seed)
    jrng = jax.random.PRNGKey(seed)

    # --- network + η + static bandwidth allocation -------------------------
    net = EdgeNetwork.drop(cfg.wireless, n, seed=seed,
                           uniform_distance=(fl.eta_mode == "equal"))
    if fl.eta_mode == "equal":
        eta = relative_frequencies(n, "equal")
    else:
        eta = relative_frequencies(n, "rates", rates=net.mean_rates())

    h_mean = cfg.wireless.rayleigh_scale * float(np.sqrt(np.pi / 2))
    mean_chans = [net.channel(i, h_mean) for i in range(n)]
    if bandwidth_policy == "optimal":
        bw = weighted_equal_rate_allocation(eta, mean_chans,
                                            cfg.wireless.total_bandwidth_hz)
    elif bandwidth_policy == "equal":
        bw = np.full(n, cfg.wireless.total_bandwidth_hz / n)
    else:
        raise ValueError(f"unknown bandwidth policy {bandwidth_policy!r}")

    # --- model / payloads ---------------------------------------------------
    params0 = model.init(jrng)
    z_bits = cfg.wireless.grad_bits or model_bits(params0)
    payload_fn = make_payload_fn(model, fl, algorithm)
    # per-UE inner learning rates α_i (paper §II-B: "easily extended to the
    # general case when UEs have diverse learning rate α_i")
    if fl.alpha_spread > 0:
        s = 1.0 + fl.alpha_spread
        alphas = fl.alpha * np.exp(rng.uniform(-np.log(s), np.log(s), size=n))
    else:
        alphas = np.full(n, fl.alpha)

    server = SemiSyncServer(params0, ServerConfig(
        n_ues=n, participants_per_round=fl.participants_per_round,
        staleness_bound=fl.staleness_bound, beta=fl.beta, mode=mode,
        staleness_discount=fl.staleness_discount))

    # --- per-UE state -------------------------------------------------------
    held_params: List[Any] = [params0 for _ in range(n)]
    d_i = np.array([min(fl.inner_batch + fl.outer_batch + fl.hessian_batch,
                        len(c)) for c in clients])
    busy_time = np.zeros(n)

    def cycle_duration(i: int) -> float:
        h = float(net.sample_fading()[i])
        tcmp = compute_time(cfg.wireless.cpu_cycles_per_sample, int(d_i[i]),
                            float(net.cpu_freq[i]))
        tcom = upload_time(z_bits, float(bw[i]), net.channel(i, h))
        return tcmp + tcom

    # --- eval ----------------------------------------------------------------
    eval_idx = rng.choice(n, size=min(eval_clients, n), replace=False)

    @jax.jit
    def _eval_one(params, batches, r):
        ploss, paux = personalized_eval(model, fl, params, batches, r)
        gout = model.loss(params, batches["outer"], r)
        gloss, gaux = gout if isinstance(gout, tuple) else (gout, {})
        acc = paux.get("acc", jnp.nan) if isinstance(paux, dict) else jnp.nan
        return ploss, gloss, acc

    def evaluate(params, r) -> Tuple[float, float, float]:
        pl, gl, ac = [], [], []
        for ci in eval_idx:
            c = clients[ci]
            batches = {"inner": c.sample(fl.inner_batch),
                       "outer": {k: v for k, v in c.test.items()}}
            p, g, a = _eval_one(params, batches, r)
            pl.append(float(p)); gl.append(float(g)); ac.append(float(a))
        acc = (float(np.nanmean(ac))
               if np.any(np.isfinite(ac)) else float("nan"))
        return float(np.mean(pl)), float(np.mean(gl)), acc

    # --- event loop ----------------------------------------------------------
    # epoch-based lazy cancellation: when the server re-distributes to a UE
    # whose upload is still in flight (τ > S forced refresh, Alg. 1 line 13),
    # the UE ABANDONS the stale computation and restarts — the old event is
    # dropped at pop time if its epoch is outdated.
    heap: List[Tuple[float, int, int, int, float, int]] = []
    epoch = np.zeros(n, dtype=np.int64)
    seq = 0
    for i in range(n):
        dur = cycle_duration(i)
        heapq.heappush(heap, (dur, seq, i, 0, dur, 0))
        seq += 1

    times, plosses, glosses, accs, rounds_at = [], [], [], [], []
    t_now = 0.0
    jr = jrng

    p0, g0, a0 = evaluate(params0, jr)
    times.append(0.0); plosses.append(p0); glosses.append(g0); accs.append(a0)
    rounds_at.append(0)

    while server.round < max_rounds and heap:
        t_now, _, ue, version, dur, ev_epoch = heapq.heappop(heap)
        if ev_epoch != epoch[ue]:
            continue                    # abandoned (stale-refresh) computation
        busy_time[ue] += dur            # only completed cycles count as busy
        jr, sub = jax.random.split(jr)
        batches = clients[ue].sample_triplet(fl.inner_batch, fl.outer_batch,
                                             fl.hessian_batch)
        payload = payload_fn(held_params[ue], batches, sub,
                             float(alphas[ue]))
        result = server.on_arrival(ue, payload)
        if result is None:
            continue
        for i in result["distribute"]:
            held_params[i] = result["params"]
            epoch[i] += 1               # cancels any in-flight computation
            dur_i = cycle_duration(i)
            heapq.heappush(heap, (t_now + dur_i, seq, i, result["round"],
                                  dur_i, int(epoch[i])))
            seq += 1
        k = result["round"]
        if k % eval_every == 0 or k == max_rounds:
            p, g, a = evaluate(result["params"], jr)
            times.append(t_now); plosses.append(p); glosses.append(g)
            accs.append(a); rounds_at.append(k)
            if verbose:
                print(f"[{name or algorithm}-{mode}] round {k:4d} "
                      f"t={t_now:8.2f}s ploss={p:.4f} gloss={g:.4f}")

    wait_frac = float(1.0 - busy_time.sum() / max(n * t_now, 1e-9))
    return SimResult(
        name=name or f"{algorithm}-{mode}",
        times=np.array(times), losses=np.array(plosses),
        global_losses=np.array(glosses), accs=np.array(accs),
        rounds=np.array(rounds_at), total_time=t_now,
        pi=server.pi_matrix(), eta_target=eta,
        eta_realised=server.realised_eta(),
        wait_fraction=max(wait_frac, 0.0),
    )
