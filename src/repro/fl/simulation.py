"""Static single-cell simulation of PerFedS² (the paper's Sec. VI setup).

Combines all the pieces:

  wireless.EdgeNetwork   — geometry, Rayleigh fading, heterogeneous CPUs
  core.bandwidth         — Theorem-2/4 allocations (or equal-split baseline)
  core.scheduler         — SchedulingPolicy (equal / rates-derived η)
  core.server            — Algorithm 1 round protocol (sync / semi / async)
  fl.engine              — batched (vmap-bucketed) payload computation
  fl.driver              — the ONE event loop (heap, drain batching, RNG
                           discipline, fused dispatch, SimResult)
  fl.client              — payload math (fedavg / fedprox / perfed)

``run_simulation`` is a thin configuration of ``fl.driver.run_event_loop``:
the ``StaticAdapter`` below contributes a frozen single-cell drop, a static
Theorem-4 (or equal-split) bandwidth allocation, and one global
``SemiSyncServer``; everything event-driven lives in the shared driver.
The mobile multi-cell path (``cfg.mobility.enabled``) configures the same
loop with a ``MobileAdapter`` — see ``fl/mobile.py``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.config import ExperimentConfig
from repro.core.bandwidth import weighted_equal_rate_allocation
from repro.core.scheduler import get_policy
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data.partition import ClientDataset
from repro.fl.driver import SimResult, TopologyAdapter, run_event_loop
from repro.fl.engine import SimulationEngine
from repro.wireless.channel import EdgeNetwork

__all__ = ["SimResult", "StaticAdapter", "run_simulation"]


class StaticAdapter(TopologyAdapter):
    """Frozen single-cell geometry + one global Algorithm-1 server."""

    def __init__(self, cfg: ExperimentConfig, n: int, *, seed: int,
                 bandwidth_policy: str, mode: str):
        fl, wl = cfg.fl, cfg.wireless
        policy = get_policy(fl.eta_mode)
        self.net = EdgeNetwork.drop(wl, n, seed=seed,
                                    uniform_distance=policy.uniform_drop)
        self.eta = policy.frequencies(n, self.net)
        h_mean = wl.rayleigh_scale * float(np.sqrt(np.pi / 2))
        mean_chans = [self.net.channel(i, h_mean) for i in range(n)]
        if bandwidth_policy == "optimal":
            self.bw = weighted_equal_rate_allocation(self.eta, mean_chans,
                                                     wl.total_bandwidth_hz)
        elif bandwidth_policy == "equal":
            self.bw = np.full(n, wl.total_bandwidth_hz / n)
        else:
            raise ValueError(f"unknown bandwidth policy {bandwidth_policy!r}")
        self._fl, self._mode, self._n = fl, mode, n
        self.server: Optional[SemiSyncServer] = None
        # open-world scenario state (inert when cfg.scenario is off); the
        # static drop has no mobility, so churn here is joins/leaves/drift
        # over a frozen geometry (bandwidth keeps the drop-time split)
        self._adaptive_a = cfg.scenario.enabled and cfg.scenario.adaptive_cell_a
        self._active_mask: Optional[np.ndarray] = None

    # --- protocol ------------------------------------------------------
    def make_servers(self, params0) -> None:
        fl = self._fl
        self.server = SemiSyncServer(params0, ServerConfig(
            n_ues=self._n, participants_per_round=fl.participants_per_round,
            staleness_bound=fl.staleness_bound, beta=fl.beta,
            mode=self._mode, staleness_discount=fl.staleness_discount))
        if self._active_mask is not None:
            self.server.ue_active[:] = self._active_mask
            self.pre_drain()

    def rounds_done(self) -> int:
        return self.server.round

    def need(self, cell: int) -> int:
        return self.server.arrivals_until_round()

    def participants(self, cell: int) -> int:
        # effective round size (== A unless clamped by the live cap)
        return self.server.target

    def on_arrival(self, cell, ue, payload):
        return self.server.on_arrival(ue, payload)

    def on_arrival_batch(self, cells, ues, payloads):
        return self.server.on_arrival_batch(ues, payloads)

    def on_round_batch(self, cell, ues, aggregate_fn):
        return self.server.on_round_batch(ues, aggregate_fn)

    def protocol(self):
        return self.server

    # --- open-world scenario hooks -------------------------------------
    def bind_active(self, mask: np.ndarray) -> None:
        self._active_mask = mask        # shared with the scenario runtime

    def pre_drain(self) -> None:
        # cap = pending + in-flight (live members whose upload is already
        # held can't produce another arrival before the close)
        if self._adaptive_a and self._active_mask is not None:
            live = int(self._active_mask.sum())
            pend = self.server.pending_ue_set()
            live_pending = sum(1 for u in pend if self._active_mask[u])
            self.server.set_live_cap(live, live - live_pending)

    def flush_ready(self):
        if not (self._adaptive_a and self._active_mask is not None):
            return []
        res = self.server.flush()
        return [res] if res is not None else []

    def on_join(self, ue: int):
        self.server.activate(ue)
        return self.server.params

    def on_leave(self, ue: int) -> None:
        self.server.deactivate(ue)

    def cell_membership(self):
        if self._active_mask is None:
            return None
        return [int(self._active_mask.sum())]


def run_simulation(cfg: ExperimentConfig, model, clients: List[ClientDataset],
                   *, algorithm: str = "perfed", mode: str = "semi",
                   bandwidth_policy: str = "optimal",
                   max_rounds: Optional[int] = None,
                   eval_every: int = 5, eval_clients: int = 8,  # 0 = no eval
                   seed: int = 0, name: Optional[str] = None,
                   verbose: bool = False,
                   payload_mode: Optional[str] = None,  # default: batched
                   engine: Optional[SimulationEngine] = None,
                   **obs_kw) -> SimResult:
    """``obs_kw`` forwards the telemetry knobs (``tracer`` / ``trace_dir``
    / ``profile_dir`` / ``reporter``) to ``run_event_loop``."""
    if cfg.mobility.enabled:
        # mobile multi-cell path (time-varying channels, handovers,
        # optional cell→cloud hierarchy) — fl/mobile.py; the static path
        # below stays bitwise untouched when the flag is off
        from repro.fl.mobile import run_mobile_simulation
        return run_mobile_simulation(
            cfg, model, clients, algorithm=algorithm, mode=mode,
            bandwidth_policy=bandwidth_policy, max_rounds=max_rounds,
            eval_every=eval_every, eval_clients=eval_clients, seed=seed,
            name=name, verbose=verbose, payload_mode=payload_mode,
            engine=engine, **obs_kw)
    adapter = StaticAdapter(cfg, len(clients), seed=seed,
                            bandwidth_policy=bandwidth_policy, mode=mode)
    return run_event_loop(cfg, model, clients, adapter,
                          algorithm=algorithm, mode=mode,
                          max_rounds=max_rounds, eval_every=eval_every,
                          eval_clients=eval_clients, seed=seed, name=name,
                          verbose=verbose, payload_mode=payload_mode,
                          engine=engine, **obs_kw)
