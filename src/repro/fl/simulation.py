"""Event-driven simulator of PerFedS² over a mobile edge network.

Combines all the pieces:

  wireless.EdgeNetwork   — geometry, Rayleigh fading, heterogeneous CPUs
  core.bandwidth         — Theorem-2/4 allocations (or equal-split baseline)
  core.scheduler         — SchedulingPolicy (equal / rates-derived η)
  core.server            — Algorithm 1 round protocol (sync / semi / async)
  fl.engine              — batched (vmap-bucketed) payload computation
  fl.client              — payload math (fedavg / fedprox / perfed)

The event loop is a priority queue over UE upload-finish times.  Each UE
holds the last model version it received; payloads are computed against that
version (⇒ real gradient staleness, exactly as in the paper).  Wall-clock
time uses Eq. (10)–(12) with fading resampled per local iteration.

This module is a *thin driver*: it drains all arrivals up to the next round
boundary (the server needs ``A − pending`` more uploads before anything can
change — no redistribution, hence no cancellation, can occur before then, so
those payloads are all computable NOW) and hands them to the
``SimulationEngine`` as one batch.  All device math lives in the engine; the
loop only moves simulated time, RNG keys, and bookkeeping.

RNG discipline: the seed key is split once into (init, payload, eval)
streams; each arrival folds its unique event id into the payload stream and
each eval folds the round index into the eval stream, so every consumer gets
an independent key and batched vs sequential runs of the same seed see the
same randomness.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.config import ExperimentConfig
from repro.core.bandwidth import weighted_equal_rate_allocation
from repro.core.scheduler import get_policy
from repro.core.server import SemiSyncServer, ServerConfig
from repro.data.partition import ClientDataset
from repro.fl.engine import SimulationEngine, ensure_engine
from repro.wireless.channel import EdgeNetwork
from repro.wireless.timing import compute_time, upload_time, model_bits


@dataclass
class SimResult:
    name: str
    times: np.ndarray            # wall-clock at each eval point [s]
    losses: np.ndarray           # personalized (PFL) eval loss
    global_losses: np.ndarray    # loss of the raw global model
    accs: np.ndarray             # accuracy if the task defines one (else nan)
    rounds: np.ndarray           # round index at each eval point
    total_time: float
    pi: np.ndarray               # realised schedule matrix
    eta_target: np.ndarray
    eta_realised: np.ndarray
    wait_fraction: float         # mean fraction of time UEs spent idle
    payload_dispatches: int = 0  # device dispatches issued by the engine
    payloads_computed: int = 0   # payloads those dispatches produced
    # mobile multi-cell extension (zeros on the static single-cell path)
    n_cells: int = 1
    handovers: int = 0           # nearest-BS re-associations during the run
    cloud_rounds: int = 0        # hierarchical cloud merges performed


def run_simulation(cfg: ExperimentConfig, model, clients: List[ClientDataset],
                   *, algorithm: str = "perfed", mode: str = "semi",
                   bandwidth_policy: str = "optimal",
                   max_rounds: Optional[int] = None,
                   eval_every: int = 5, eval_clients: int = 8,  # 0 = no eval
                   seed: int = 0, name: Optional[str] = None,
                   verbose: bool = False,
                   payload_mode: Optional[str] = None,  # default: batched
                   engine: Optional[SimulationEngine] = None) -> SimResult:
    if cfg.mobility.enabled:
        # mobile multi-cell path (time-varying channels, handovers,
        # optional cell→cloud hierarchy) — fl/mobile.py; the static path
        # below stays bitwise untouched when the flag is off
        from repro.fl.mobile import run_mobile_simulation
        return run_mobile_simulation(
            cfg, model, clients, algorithm=algorithm, mode=mode,
            bandwidth_policy=bandwidth_policy, max_rounds=max_rounds,
            eval_every=eval_every, eval_clients=eval_clients, seed=seed,
            name=name, verbose=verbose, payload_mode=payload_mode,
            engine=engine)
    fl = cfg.fl
    n = len(clients)
    max_rounds = max_rounds or fl.rounds
    rng = np.random.default_rng(seed)
    # one independent key per consumer (init / payloads / evals)
    init_key, payload_key, eval_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)

    # --- network + η + static bandwidth allocation -------------------------
    policy = get_policy(fl.eta_mode)
    net = EdgeNetwork.drop(cfg.wireless, n, seed=seed,
                           uniform_distance=policy.uniform_drop)
    eta = policy.frequencies(n, net)

    h_mean = cfg.wireless.rayleigh_scale * float(np.sqrt(np.pi / 2))
    mean_chans = [net.channel(i, h_mean) for i in range(n)]
    if bandwidth_policy == "optimal":
        bw = weighted_equal_rate_allocation(eta, mean_chans,
                                            cfg.wireless.total_bandwidth_hz)
    elif bandwidth_policy == "equal":
        bw = np.full(n, cfg.wireless.total_bandwidth_hz / n)
    else:
        raise ValueError(f"unknown bandwidth policy {bandwidth_policy!r}")

    # --- model / engine -----------------------------------------------------
    params0 = model.init(init_key)
    z_bits = cfg.wireless.grad_bits or model_bits(
        params0, cfg.wireless.bits_per_param)
    engine = ensure_engine(engine, model, fl, algorithm=algorithm,
                           payload_mode=payload_mode)
    # snapshot so SimResult reports THIS run's dispatch counts even when the
    # engine (and its lifetime counters) is shared across a sweep
    disp0, pay0 = engine.dispatches, engine.payloads_computed
    # per-UE inner learning rates α_i (paper §II-B: "easily extended to the
    # general case when UEs have diverse learning rate α_i")
    if fl.alpha_spread > 0:
        s = 1.0 + fl.alpha_spread
        alphas = fl.alpha * np.exp(rng.uniform(-np.log(s), np.log(s), size=n))
    else:
        alphas = np.full(n, fl.alpha)

    server = SemiSyncServer(params0, ServerConfig(
        n_ues=n, participants_per_round=fl.participants_per_round,
        staleness_bound=fl.staleness_bound, beta=fl.beta, mode=mode,
        staleness_discount=fl.staleness_discount))

    # --- per-UE state -------------------------------------------------------
    held_params: List[Any] = [params0 for _ in range(n)]
    d_i = np.array([min(fl.inner_batch + fl.outer_batch + fl.hessian_batch,
                        len(c)) for c in clients])
    busy_time = np.zeros(n)
    # batch shapes are a pure function of the shard size; a round whose UEs
    # share one signature can take the fused path, mixed rounds fall back to
    # bucketed payloads (rule lives on ClientDataset, next to the sampler)
    batch_sig = [c.triplet_sizes(fl.inner_batch, fl.outer_batch,
                                 fl.hessian_batch) for c in clients]

    def cycle_duration(i: int) -> float:
        h = float(net.sample_fading()[i])
        tcmp = compute_time(cfg.wireless.cpu_cycles_per_sample, int(d_i[i]),
                            float(net.cpu_freq[i]))
        tcom = upload_time(z_bits, float(bw[i]), net.channel(i, h))
        return tcmp + tcom

    # --- eval ----------------------------------------------------------------
    eval_idx = rng.choice(n, size=min(eval_clients, n), replace=False)

    def evaluate(params, k: int) -> Tuple[float, float, float]:
        r = jax.random.fold_in(eval_key, k)
        pl, gl, ac = [], [], []
        for ci in eval_idx:
            c = clients[ci]
            r, sub = jax.random.split(r)
            batches = {"inner": c.sample(fl.inner_batch),
                       "outer": {k2: v for k2, v in c.test.items()}}
            p, g, a = engine.eval_one(params, batches, sub)
            pl.append(float(p)); gl.append(float(g)); ac.append(float(a))
        acc = (float(np.nanmean(ac))
               if np.any(np.isfinite(ac)) else float("nan"))
        return float(np.mean(pl)), float(np.mean(gl)), acc

    # --- event loop ----------------------------------------------------------
    # epoch-based lazy cancellation: when the server re-distributes to a UE
    # whose upload is still in flight (τ > S forced refresh, Alg. 1 line 13),
    # the UE ABANDONS the stale computation and restarts — the old event is
    # dropped at pop time if its epoch is outdated.
    heap: List[Tuple[float, int, int, int, float, int]] = []
    epoch = np.zeros(n, dtype=np.int64)
    seq = 0
    for i in range(n):
        dur = cycle_duration(i)
        heapq.heappush(heap, (dur, seq, i, 0, dur, 0))
        seq += 1

    times, plosses, glosses, accs, rounds_at = [], [], [], [], []
    t_now = 0.0
    do_eval = eval_every > 0            # 0 → pure-throughput mode, no evals

    if do_eval:
        p0, g0, a0 = evaluate(params0, 0)
        times.append(0.0); plosses.append(p0); glosses.append(g0)
        accs.append(a0); rounds_at.append(0)

    while server.round < max_rounds and heap:
        # ---- drain one round's worth of arrivals ---------------------------
        # The server advances only on its (A − pending)-th upload; until then
        # no distribution happens, so no epoch can change and no new event
        # can precede the ones already queued — the next `need` epoch-valid
        # pops are exactly the arrivals the sequential loop would process,
        # and their payloads are all computable now, as one batch.
        need = server.arrivals_until_round()
        batch: List[Tuple[float, int, int, float]] = []  # (t, ue, seq, dur)
        while heap and len(batch) < need:
            t, sq, ue, _version, dur, ev_epoch = heapq.heappop(heap)
            if ev_epoch != epoch[ue]:
                continue                # abandoned (stale-refresh) cycle
            batch.append((t, ue, sq, dur))
        if not batch:
            break

        held = [held_params[ue] for _, ue, _, _ in batch]
        triplets = [clients[ue].sample_triplet(fl.inner_batch, fl.outer_batch,
                                               fl.hessian_batch)
                    for _, ue, _, _ in batch]
        a_i = [alphas[ue] for _, ue, _, _ in batch]

        def handle(result) -> None:
            nonlocal seq
            for i in result["distribute"]:
                held_params[i] = result["params"]
                epoch[i] += 1           # cancels any in-flight computation
                dur_i = cycle_duration(i)
                heapq.heappush(heap, (t_now + dur_i, seq, i, result["round"],
                                      dur_i, int(epoch[i])))
                seq += 1
            k = result["round"]
            if do_eval and (k % eval_every == 0 or k == max_rounds):
                p, g, a = evaluate(result["params"], k)
                times.append(t_now); plosses.append(p); glosses.append(g)
                accs.append(a); rounds_at.append(k)
                if verbose:
                    print(f"[{name or algorithm}-{mode}] round {k:4d} "
                          f"t={t_now:8.2f}s ploss={p:.4f} gloss={g:.4f}")

        if (engine.payload_mode == "batched" and len(batch) == server.a
                and server.a <= engine.max_bucket
                and len({batch_sig[ue] for _, ue, _, _ in batch}) == 1):
            # fused fast path: the whole round — per-arrival RNG, vmapped
            # payloads, Eq. (8) stale aggregation — fuses into one device
            # dispatch per model-version group
            for t, ue, _sq, dur in batch:
                t_now = t
                busy_time[ue] += dur    # only completed cycles count as busy

            def aggregate(params, weights):
                return engine.round_update(
                    params, held, triplets, [sq for _, _, sq, _ in batch],
                    a_i, weights, beta=fl.beta, base_key=payload_key)

            handle(server.on_round_batch([ue for _, ue, _, _ in batch],
                                         aggregate))
        else:
            payloads = engine.compute_payloads(
                held, triplets,
                [jax.random.fold_in(payload_key, sq)
                 for _, _, sq, _ in batch],
                a_i)
            # ---- feed the server in arrival order --------------------------
            for (t, ue, _sq, dur), payload in zip(batch, payloads):
                t_now = t
                busy_time[ue] += dur    # only completed cycles count as busy
                result = server.on_arrival(ue, payload)
                if result is not None:
                    handle(result)

    # drain the async dispatch queue so wall-clock timings of this function
    # include all device work it issued (jit dispatch is asynchronous)
    jax.block_until_ready(jax.tree.leaves(server.params))

    wait_frac = float(1.0 - busy_time.sum() / max(n * t_now, 1e-9))
    return SimResult(
        name=name or f"{algorithm}-{mode}",
        times=np.array(times), losses=np.array(plosses),
        global_losses=np.array(glosses), accs=np.array(accs),
        rounds=np.array(rounds_at), total_time=t_now,
        pi=server.pi_matrix(), eta_target=eta,
        eta_realised=server.realised_eta(),
        wait_fraction=max(wait_frac, 0.0),
        payload_dispatches=engine.dispatches - disp0,
        payloads_computed=engine.payloads_computed - pay0,
    )
