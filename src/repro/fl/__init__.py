from repro.fl.algorithms import ALGORITHMS, algorithm_name
from repro.fl.client import global_eval, make_payload_fn, personalized_eval
from repro.fl.driver import TopologyAdapter, run_event_loop
from repro.fl.engine import SimulationEngine, bucket_size
from repro.fl.simulation import SimResult, run_simulation

__all__ = [
    "ALGORITHMS",
    "SimResult",
    "SimulationEngine",
    "TopologyAdapter",
    "algorithm_name",
    "bucket_size",
    "global_eval",
    "make_payload_fn",
    "personalized_eval",
    "run_event_loop",
    "run_simulation",
]
