from repro.fl.client import make_payload_fn, personalized_eval, global_eval
from repro.fl.algorithms import ALGORITHMS, algorithm_name
from repro.fl.engine import SimulationEngine, bucket_size
from repro.fl.driver import run_event_loop, TopologyAdapter
from repro.fl.simulation import run_simulation, SimResult
