"""SimulationEngine — batched device math under the event-driven simulator.

The simulator (``fl/simulation.py``) is a thin host-side driver: it pops
arrival events, asks this engine for the corresponding client payloads, and
feeds them to the Algorithm-1 server.  The engine owns every device
dispatch:

* **sequential** mode — one jitted payload call per arrival (the original
  simulator behaviour; kept as the correctness/throughput reference).
* **batched** mode — a whole round of arrivals fuses into one device
  dispatch per *model-version group* (``round_update``): per-arrival RNG
  derivation, the ``vmap``-ed payload computation, and the Eq. (8) masked
  stale aggregation (``kernels/stale_aggregate``) all run inside jitted
  functions.  Lanes sharing a version are grouped so the model weights are
  read once per group (the payload math is memory-bound on weights); when
  versions are mostly distinct, a single all-lanes dispatch carries each
  lane's own flat version instead.  Arrival counts are padded up to
  power-of-2 *bucket* sizes (1, 2, 4, ... ``max_bucket``) with zero
  aggregation weight on padded lanes, so the jit cache holds one entry per
  (bucket, shape-signature) instead of recompiling per batch size — N
  concurrent UE payloads cost one-or-few dispatches instead of N.

Model versions move through the all-lanes path as flat f32 vectors (a
cached ``TreeFlattener`` per structure + an id-keyed cache of already-
flattened versions), so a round touches the host only to stack its inputs.

Numerics are identical to the sequential path up to XLA's batching of the
same ops (the equivalence test in ``tests/test_engine.py`` pins this), and
per-arrival RNG keys are derived from fold_in(key, event id), so batched
and sequential runs of the same seed produce the same trajectories.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.fl.client import make_payload_fn, personalized_eval
from repro.kernels.stale_aggregate import stale_aggregate_tree
from repro.obs import trace as obs
from repro.utils.tree import TreeFlattener

__all__ = ["SimulationEngine", "bucket_size", "ensure_engine"]


def bucket_size(m: int, max_bucket: int = 256) -> int:
    """Smallest power of two ≥ m, capped at ``max_bucket``."""
    if m <= 0:
        raise ValueError("empty batch")
    b = 1
    while b < m:
        b <<= 1
    return min(b, max_bucket)


def ensure_engine(engine: Optional["SimulationEngine"], model, fl, *,
                  algorithm: str,
                  payload_mode: Optional[str]) -> "SimulationEngine":
    """Build a fresh engine, or validate a caller-supplied one against the
    run's (model, algorithm, FLConfig, payload_mode) — shared by the static
    (``fl/simulation.py``) and mobile (``fl/mobile.py``) drivers."""
    import dataclasses

    if engine is None:
        return SimulationEngine(model, fl, algorithm,
                                payload_mode=payload_mode or "batched")
    if engine.algorithm != algorithm or engine.model is not model:
        raise ValueError(
            f"engine was built for algorithm {engine.algorithm!r} and "
            f"its own model; cannot run algorithm {algorithm!r} with it")
    # the engine's compiled payload fns bake in its FLConfig — only the
    # scheduling-side eta_mode may differ between runs sharing an engine
    if dataclasses.replace(engine.fl, eta_mode=fl.eta_mode) != fl:
        raise ValueError("engine.fl differs from cfg.fl beyond eta_mode; "
                         "build a fresh SimulationEngine for this config")
    if payload_mode is not None and payload_mode != engine.payload_mode:
        raise ValueError(
            f"payload_mode={payload_mode!r} conflicts with the supplied "
            f"engine's mode {engine.payload_mode!r}")
    return engine


def _shape_signature(batches: Any) -> Tuple:
    """Hashable (path-ordered) leaf shape+dtype signature of a batch tree."""
    leaves, treedef = jax.tree_util.tree_flatten(batches)
    # read .dtype directly — np.asarray would pull device arrays to host
    return (treedef, tuple((x.shape, np.dtype(x.dtype).str)
                           for x in leaves))


def _leading_len(tree: Any) -> int:
    """Length of the leading (lane) axis of a stacked batch tree."""
    return int(jax.tree_util.tree_leaves(tree)[0].shape[0])


def _stack_trees(trees: Sequence[Any]):
    def stack(*xs):
        if all(isinstance(x, np.ndarray) for x in xs):
            return jnp.asarray(np.stack(xs))       # one host→device transfer
        return jnp.stack([jnp.asarray(x) for x in xs])
    return jax.tree.map(stack, *trees)


class SimulationEngine:
    """Vectorized payload computation for a (model, FLConfig, algorithm)."""

    def __init__(self, model, fl: FLConfig, algorithm: str, *,
                 payload_mode: str = "batched", max_bucket: int = 256,
                 agg_backend: str = "auto"):
        if payload_mode not in ("batched", "sequential"):
            raise ValueError(f"unknown payload_mode {payload_mode!r}")
        self.model = model
        self.fl = fl
        self.algorithm = algorithm
        self.payload_mode = payload_mode
        self.max_bucket = max_bucket
        self.agg_backend = agg_backend
        self._raw = make_payload_fn(model, fl, algorithm, jit=False)
        self._single = jax.jit(self._raw)
        # one jitted vmapped callable; jit's cache keys on input shapes, so
        # it holds exactly one entry per (bucket size, batch signature)
        self._batched = jax.jit(jax.vmap(self._raw, in_axes=(0, 0, 0, 0)))
        self._batched_keyed = None
        self._batched_keyed_shared = None
        self._round_fns: Dict[TreeFlattener, Any] = {}
        self._group_fn = None
        self._combine_fn = None
        # id-keyed cache of flattened model versions; holding the tree ref
        # keeps ids stable for the cache's lifetime
        self._flat_versions: Dict[int, Tuple[Any, jax.Array]] = {}
        self._eval_fn = None
        self._eval_vfn = None
        self.dispatches = 0            # device calls issued (for benchmarks)
        self.payloads_computed = 0
        self.eval_dispatches = 0       # eval calls (kept off payload count)

    # ------------------------------------------------------------------
    # evaluation (jitted once per engine, reused across simulations)
    # ------------------------------------------------------------------
    def _eval_raw(self):
        model, fl = self.model, self.fl

        def _eval(params, batches, r):
            ploss, paux = personalized_eval(model, fl, params, batches, r)
            gout = model.loss(params, batches["outer"], r)
            gloss, _ = gout if isinstance(gout, tuple) else (gout, {})
            acc = (paux.get("acc", jnp.nan)
                   if isinstance(paux, dict) else jnp.nan)
            return ploss, gloss, acc

        return _eval

    def eval_one(self, params, batches, rng):
        """(personalized loss, global loss, accuracy) for one client."""
        if self._eval_fn is None:
            self._eval_fn = jax.jit(self._eval_raw())
        self.eval_dispatches += 1
        obs.CURRENT.add("engine.dispatch.eval_one")
        return obs.CURRENT.device_call("engine.eval", self._eval_fn,
                                       params, batches, rng)

    def eval_many(self, params, batches_list: Sequence[Any],
                  rngs: Sequence[jax.Array]
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Evaluate a cohort of clients against ONE ``params``: per-client
        (personalized loss, global loss, accuracy) as ``[m]`` arrays.

        Clients sharing a batch-shape signature are stacked and evaluated
        as one vmapped dispatch with the model weights broadcast
        (``in_axes=(None, 0, 0)``) — an eval point over a uniform cohort
        costs 1 device call instead of m.  Singleton groups go through the
        exact same jitted scalar function as ``eval_one``, so trajectories
        of shape-heterogeneous cohorts (and the pre-batching goldens) are
        reproduced bit for bit.
        """
        m = len(batches_list)
        assert m == len(rngs)
        pl = np.zeros(m)
        gl = np.zeros(m)
        ac = np.zeros(m)
        groups: Dict[Tuple, List[int]] = {}
        for i, b in enumerate(batches_list):
            groups.setdefault(_shape_signature(b), []).append(i)
        for idx in groups.values():
            if len(idx) == 1:
                i = idx[0]
                p, g, a = self.eval_one(params, batches_list[i], rngs[i])
                pl[i], gl[i], ac[i] = float(p), float(g), float(a)
                continue
            if self._eval_vfn is None:
                self._eval_vfn = jax.jit(
                    jax.vmap(self._eval_raw(), in_axes=(None, 0, 0)))
            batches_b = _stack_trees([batches_list[i] for i in idx])
            rngs_b = jnp.stack([rngs[i] for i in idx])
            obs.CURRENT.add("engine.dispatch.eval_vmap")
            p, g, a = obs.CURRENT.device_call(
                "engine.eval", self._eval_vfn, params, batches_b, rngs_b)
            self.eval_dispatches += 1
            # eval results are consumed on host by design: this is the
            # one deliberate sync point per eval sweep
            pl[idx] = np.asarray(p)   # simlint: disable=SIM202 -- eval sync
            gl[idx] = np.asarray(g)   # simlint: disable=SIM202 -- eval sync
            ac[idx] = np.asarray(a)   # simlint: disable=SIM202 -- eval sync
        return pl, gl, ac

    # ------------------------------------------------------------------
    # per-arrival payloads (sequential mode / partial batches / tests)
    # ------------------------------------------------------------------
    def compute_payloads(self, params_list: Sequence[Any],
                         batches_list: Sequence[Any],
                         rngs: Sequence[jax.Array],
                         alphas: Sequence[float]) -> List[Any]:
        """Payload pytree per arrival; inputs are parallel per-arrival lists.

        ``params_list[i]`` is the model version arrival ``i`` computed
        against (staleness ⇒ entries may differ), ``rngs[i]`` its private
        key, ``alphas[i]`` its inner learning rate α_i.
        """
        m = len(params_list)
        assert m == len(batches_list) == len(rngs) == len(alphas)
        if m == 0:
            return []
        if self.payload_mode == "sequential":
            tr = obs.CURRENT
            out = [tr.device_call("engine.payload", self._single,
                                  p, b, r, float(a))
                   for p, b, r, a in zip(params_list, batches_list, rngs,
                                         alphas)]
            self.dispatches += m
            self.payloads_computed += m
            tr.add("engine.dispatch.sequential", m)
            return out

        # group by batch-shape signature (stragglers with short shards get
        # their own bucket; the common case is a single group)
        groups: Dict[Tuple, List[int]] = {}
        for i, b in enumerate(batches_list):
            groups.setdefault(_shape_signature(b), []).append(i)

        results: List[Any] = [None] * m
        for idx in groups.values():
            if len(idx) == 1:
                # a singleton group rides the exact scalar jit (as
                # eval_many does) — no bucket padding, no stack, no
                # per-lane extraction
                i = idx[0]
                obs.CURRENT.add("engine.dispatch.single")
                results[i] = obs.CURRENT.device_call(
                    "engine.payload", self._single, params_list[i],
                    batches_list[i], rngs[i], float(alphas[i]))
                self.dispatches += 1
                self.payloads_computed += 1
                continue
            for lo in range(0, len(idx), self.max_bucket):
                self._run_bucket(idx[lo:lo + self.max_bucket], params_list,
                                 batches_list, rngs, alphas, results)
        return results

    def _run_bucket(self, idx: List[int], params_list, batches_list, rngs,
                    alphas, results: List[Any]) -> None:
        k = len(idx)
        bucket = bucket_size(k, self.max_bucket)
        # pad by repeating the first arrival — padded lanes are discarded
        pad = idx + [idx[0]] * (bucket - k)
        params_b = _stack_trees([params_list[i] for i in pad])
        batches_b = _stack_trees([batches_list[i] for i in pad])
        rngs_b = jnp.stack([rngs[i] for i in pad])
        alphas_b = jnp.asarray([float(alphas[i]) for i in pad],
                               jnp.float32)
        obs.CURRENT.add("engine.dispatch.bucket")
        out = obs.CURRENT.device_call("engine.payload", self._batched,
                                      params_b, batches_b, rngs_b, alphas_b)
        self.dispatches += 1
        self.payloads_computed += k
        for lane, i in enumerate(idx):
            results[i] = jax.tree.map(lambda x, lane=lane: x[lane], out)

    # ------------------------------------------------------------------
    # stacked payloads (batch-wise protocol feed)
    # ------------------------------------------------------------------
    def _get_batched_keyed(self):
        """Like ``_batched`` but derives each lane's key INSIDE the jit
        (``fold_in(base_key, seq)`` with the base key broadcast), so the
        host never builds a per-lane key list."""
        if self._batched_keyed is None:
            raw = self._raw

            def one(p, b, s, a, key):
                return raw(p, b, jax.random.fold_in(key, s), a)

            self._batched_keyed = jax.jit(
                jax.vmap(one, in_axes=(0, 0, 0, 0, None)))
        return self._batched_keyed

    def _get_batched_keyed_shared(self):
        """``_batched_keyed`` with the params BROADCAST (``in_axes=None``):
        the common case is every lane of a drain holding the same model
        version, where stacking k copies of the tree on the host costs
        more than the payload math itself."""
        if self._batched_keyed_shared is None:
            raw = self._raw

            def one(p, b, s, a, key):
                return raw(p, b, jax.random.fold_in(key, s), a)

            self._batched_keyed_shared = jax.jit(
                jax.vmap(one, in_axes=(None, 0, 0, 0, None)))
        return self._batched_keyed_shared

    def compute_payloads_stacked(self, params_list: Sequence[Any],
                                 groups: Sequence[Tuple[List[int], Any]],
                                 seqs: Sequence[int],
                                 alphas: Sequence[float],
                                 base_key: jax.Array) -> Any:
        """Payloads of one drained batch as ONE stacked pytree (leading
        lane axis, drain arrival order) — the batch-wise feed's engine
        entry: no per-lane payload tree is ever built, so the driver can
        hand the result straight to ``on_arrival_batch``.

        ``groups`` covers every lane exactly once as ``(lanes,
        batches_stacked)`` pairs: ``lanes`` are global lane indices and
        ``batches_stacked`` the matching client batches with a leading
        lane axis (``data.partition.sample_triplet_many``).
        ``params_list``/``seqs``/``alphas`` stay per-lane.  Singleton
        chunks ride the exact scalar ``_single`` jit.
        """
        m = len(params_list)
        assert m == len(seqs) == len(alphas) and m > 0
        parts: List[Any] = []
        order: List[int] = []
        for lanes, batches in groups:
            for lo in range(0, len(lanes), self.max_bucket):
                chunk = lanes[lo:lo + self.max_bucket]
                rows = np.arange(lo, lo + len(chunk))
                parts.append(self._stacked_bucket(
                    chunk, rows, batches, params_list, seqs, alphas,
                    base_key))
                order.extend(chunk)
        if order == list(range(m)):
            # single signature: chunk order IS arrival order — concat only
            # (no inverse-permute gather; these trees are [k, model]-sized)
            if len(parts) == 1:
                return parts[0]
            return jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        # concat in chunk order, then inverse-permute to arrival order —
        # aggregation sums rows in stacked order, so this keeps the batch
        # feed's summation order identical to the per-arrival path
        pos = np.empty(m, dtype=np.int64)
        # simlint: disable-next=SIM202 -- order is a host int list
        pos[np.asarray(order, dtype=np.int64)] = np.arange(m)
        return jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0)[pos], *parts)

    def _stacked_bucket(self, chunk: List[int], rows: np.ndarray, batches,
                        params_list, seqs, alphas, base_key) -> Any:
        """One padded vmapped dispatch over ``chunk``; returns the valid
        ``[k, ...]`` rows of the stacked payload output."""
        k = len(chunk)
        if k == 1:
            i = chunk[0]
            b = jax.tree.map(lambda x: x[rows[0]], batches)
            obs.CURRENT.add("engine.dispatch.single")
            out = obs.CURRENT.device_call(
                "engine.payload", self._single, params_list[i], b,
                jax.random.fold_in(base_key, int(seqs[i])),
                float(alphas[i]))
            self.dispatches += 1
            self.payloads_computed += 1
            return jax.tree.map(lambda x: x[None], out)
        bucket = bucket_size(k, self.max_bucket)
        pad = list(chunk) + [chunk[0]] * (bucket - k)
        # dedupe model versions by tree identity (distribution hands every
        # lane of a version the SAME object): a drain holds at most
        # ~staleness-bound distinct versions, so stacking per-version and
        # gathering beats stacking k whole trees — and the usual
        # single-version bucket skips params stacking entirely
        uniq: List[Any] = []
        vidx: List[int] = []
        seen: Dict[int, int] = {}
        for i in pad:
            t = params_list[i]
            j = seen.get(id(t))
            if j is None:
                j = seen[id(t)] = len(uniq)
                uniq.append(t)
            vidx.append(j)
        if bucket == k and rows[0] == 0 and _leading_len(batches) == k:
            batches_b = batches               # whole group, no padding
        else:
            ridx = np.concatenate(
                [rows, np.full(bucket - k, rows[0], dtype=np.int64)])
            batches_b = jax.tree.map(lambda x: x[ridx], batches)
        seqs_b = jnp.asarray([int(seqs[i]) for i in pad], jnp.int32)
        alphas_b = jnp.asarray([float(alphas[i]) for i in pad],
                               jnp.float32)
        if len(uniq) == 1:
            obs.CURRENT.add("engine.dispatch.stacked_shared")
            out = obs.CURRENT.device_call(
                "engine.payload", self._get_batched_keyed_shared(),
                uniq[0], batches_b, seqs_b, alphas_b, base_key)
        else:
            vj = jnp.asarray(vidx, jnp.int32)
            params_b = jax.tree.map(
                lambda *xs: jnp.stack(xs)[vj], *uniq)
            obs.CURRENT.add("engine.dispatch.stacked_keyed")
            out = obs.CURRENT.device_call(
                "engine.payload", self._get_batched_keyed(),
                params_b, batches_b, seqs_b, alphas_b, base_key)
        self.dispatches += 1
        self.payloads_computed += k
        if bucket == k:
            return out
        return jax.tree.map(lambda x: x[:k], out)

    # ------------------------------------------------------------------
    # fused round update (batched mode fast path)
    # ------------------------------------------------------------------
    # at most ~staleness-bound distinct versions are live at once; a small
    # multiple of the bucket leaves headroom without pinning dead models
    _FLAT_CACHE_LIMIT = 64

    def _cache_flat(self, tree, flat: jax.Array) -> None:
        while len(self._flat_versions) >= self._FLAT_CACHE_LIMIT:
            # evict oldest first (dict preserves insertion order) — each
            # entry pins a full model copy, so wholesale retention would
            # hold every historical version of a long sweep in memory
            self._flat_versions.pop(next(iter(self._flat_versions)))
        self._flat_versions[id(tree)] = (tree, flat)

    def _flat_of(self, tree, flattener: TreeFlattener) -> jax.Array:
        ent = self._flat_versions.get(id(tree))
        if ent is not None:
            return ent[1]
        flat = flattener.flatten(tree)
        self._cache_flat(tree, flat)
        return flat

    def _get_round_fn(self, flattener: TreeFlattener):
        """All-lanes path: every lane carries its own flat model version."""
        fn = self._round_fns.get(flattener)
        if fn is None:
            raw, backend = self._raw, self.agg_backend

            def round_fn(p_tree, version_tuple, batches, seqs, alphas,
                         weights, beta, key):
                # stacking happens inside the trace: the bucket-length tuple
                # of flat model versions costs zero extra dispatches
                versions = jnp.stack(version_tuple)

                def one(v, b, s, a):
                    params = flattener.unflatten(v)
                    r = jax.random.fold_in(key, s)
                    return raw(params, b, r, a)

                payloads = jax.vmap(one)(versions, batches, seqs, alphas)
                new_tree = stale_aggregate_tree(p_tree, payloads, weights,
                                                beta=beta, backend=backend)
                return new_tree, flattener.flatten(new_tree)

            fn = self._round_fns[flattener] = jax.jit(round_fn)
        return fn

    def _get_group_fn(self):
        """Shared-version path: params broadcast (in_axes=None), the model
        weights are read ONCE per version group instead of once per lane —
        the payload math is memory-bound on weights, so this is the big
        lever at scale.  Returns the group's weighted payload sum."""
        if self._group_fn is None:
            raw = self._raw

            def gfn(params, batches, seqs, alphas, weights, key):
                def one(b, s, a):
                    r = jax.random.fold_in(key, s)
                    return raw(params, b, r, a)

                pay = jax.vmap(one, in_axes=(0, 0, 0))(batches, seqs, alphas)
                return jax.tree.map(
                    lambda bl: jnp.tensordot(weights,
                                             bl.astype(jnp.float32), axes=1),
                    pay)

            self._group_fn = jax.jit(gfn)
        return self._group_fn

    def _get_combine_fn(self):
        """w ← w − scale·Σ_g partial_g — jit recompiles per group count."""
        if self._combine_fn is None:

            def cfn(params, scale, *partials):
                tot = jax.tree.map(lambda *xs: sum(xs[1:], xs[0]), *partials)
                return jax.tree.map(
                    lambda p, t: (p.astype(jnp.float32) - scale * t)
                    .astype(jnp.asarray(p).dtype), params, tot)

            self._combine_fn = jax.jit(cfn)
        return self._combine_fn

    def _round_grouped(self, server_params, groups, gparams, batches_list,
                       seqs, alphas, weights, beta, base_key):
        gfn = self._get_group_fn()
        partials = []
        for g, group_lanes in enumerate(groups):
            bucket = bucket_size(len(group_lanes), self.max_bucket)
            lanes = group_lanes + [group_lanes[0]] * (bucket -
                                                      len(group_lanes))
            batches = _stack_trees([batches_list[i] for i in lanes])
            seqs_b = jnp.asarray([int(seqs[i]) for i in lanes], jnp.int32)
            alphas_b = jnp.asarray([float(alphas[i]) for i in lanes],
                                   jnp.float32)
            w = np.zeros(bucket, np.float32)
            w[:len(group_lanes)] = [float(weights[i]) for i in group_lanes]
            obs.CURRENT.add("engine.dispatch.group")
            partials.append(obs.CURRENT.device_call(
                "engine.round", gfn, gparams[g], batches, seqs_b,
                alphas_b, jnp.asarray(w), base_key))
            self.dispatches += 1
        # simlint: disable-next=SIM202 -- weights is a host float list
        a_tot = max(float(np.asarray(weights, np.float32).sum()), 1.0)
        self.dispatches += 1                       # the combine call below
        obs.CURRENT.add("engine.dispatch.combine")
        return obs.CURRENT.device_call(
            "engine.round", self._get_combine_fn(),
            server_params, jnp.float32(beta / a_tot), *partials)

    def round_update(self, server_params, params_list: Sequence[Any],
                     batches_list: Sequence[Any], seqs: Sequence[int],
                     alphas: Sequence[float], weights: np.ndarray, *,
                     beta: float, base_key: jax.Array):
        """Fused round: payloads of a full round + Eq. (8) update, in one
        device dispatch per model-version group (one total when versions
        are mostly distinct).

        ``weights`` are the server's aggregation weights (1s, or λ^τ
        staleness discounts); padded lanes get weight 0 so they never touch
        the update.  Returns the new global params pytree.
        """
        m = len(params_list)
        if m > self.max_bucket:
            raise ValueError(f"round of {m} arrivals exceeds max_bucket="
                             f"{self.max_bucket}")
        # group lanes by the model version they computed against
        index: Dict[int, int] = {}
        groups: List[List[int]] = []
        gparams: List[Any] = []
        for i, t in enumerate(params_list):
            g = index.get(id(t))
            if g is None:
                g = index[id(t)] = len(groups)
                groups.append([])
                gparams.append(t)
            groups[g].append(i)

        self.payloads_computed += m
        if len(groups) <= max(1, m // 2):
            # enough version sharing to win from broadcasting the weights
            return self._round_grouped(server_params, groups, gparams,
                                       batches_list, seqs, alphas, weights,
                                       beta, base_key)

        flattener = TreeFlattener.for_tree(server_params)
        bucket = bucket_size(m, self.max_bucket)
        lanes = list(range(m)) + [0] * (bucket - m)
        versions = tuple(self._flat_of(params_list[i], flattener)
                         for i in lanes)
        batches = _stack_trees([batches_list[i] for i in lanes])
        seqs_b = jnp.asarray([int(seqs[i]) for i in lanes], jnp.int32)
        alphas_b = jnp.asarray([float(alphas[i]) for i in lanes],
                               jnp.float32)
        w = np.zeros(bucket, np.float32)
        # simlint: disable-next=SIM202 -- weights is a host float list
        w[:m] = np.asarray(weights, np.float32)
        obs.CURRENT.add("engine.dispatch.round")
        new_params, new_flat = obs.CURRENT.device_call(
            "engine.round", self._get_round_fn(flattener),
            server_params, versions, batches, seqs_b, alphas_b,
            jnp.asarray(w), float(beta), base_key)
        self.dispatches += 1
        self._cache_flat(new_params, new_flat)
        return new_params
