"""Unified event-loop driver for the PerFedS² simulators.

``run_simulation`` (static single cell) and ``run_mobile_simulation``
(mobile multi-cell) used to be two ~300-line near-copies of the same loop,
and the divergence produced real bugs: in-flight uploads were credited to a
UE's *post-handover* cell, making ``HierarchicalServer.on_arrival``'s
departed-UE path unreachable.  Both entry points are now thin
configurations of ``run_event_loop``, parameterized by a small
``TopologyAdapter`` — so the semi-synchronous machinery lives exactly once:

* the priority queue over upload-finish times with epoch-based lazy
  cancellation (τ > S forced refresh abandons in-flight work, Alg. 1 l. 13);
* the drain-until-round-closes batching (the server advances only on its
  (A − pending)-th upload, so no distribution — hence no cancellation and
  no membership effect on queued events — can precede the drained arrivals;
  their payloads are all computable NOW, as one engine batch: paper Alg. 1
  / Eq. 8, the invariant that makes PerFedS² fast to simulate);
* the fused-vs-bucketed dispatch decision (a whole round matching one
  cell's ``A`` with a single batch signature takes the engine's
  one-dispatch-per-version-group ``round_update`` path);
* ``handle`` / ``evaluate`` / cycle-duration pricing, α_i spreading, RNG
  discipline (independent init/payload/eval streams, ``fold_in`` per event
  id / round), and ``SimResult`` assembly.

Arrival routing: every heap event is stamped with the cell that dispatched
it (the UE's association at *cycle start*).  An upload that was in flight
during a handover therefore arrives at the cell whose round it was computed
against — the departed-UE path in ``core/hierarchy.py`` now fires — and the
drain's per-cell arrival counting can never be skewed by mid-drain
handovers.

Requeue pricing is batched: a requeue of k UEs draws ONE ``[k, n]`` fading
matrix (bitwise identical to k sequential ``sample_fading()`` calls —
drawn in bounded row blocks so a 16k-UE initial fill never materialises an
``[n, n]`` matrix) and runs Eq. (10)–(11) vectorized over the k lanes,
instead of one full-vector RNG draw plus python-scalar channel math per UE
per requeue (``benchmarks/requeue.py`` measures the win at 1024 UEs).  The
d^{−κ} path-loss factors stay on python-scalar pow so every lane is
bitwise identical to the legacy per-UE loop (see
``wireless.channel.pathloss_pow``) — cached as one full vector while the
topology is frozen, priced per requeued lane once mobility starts
replacing the distances array.  Departed-UE restarts are batched the same
way: all UEs handed over mid-flight during one drain are re-priced with a
single ``cycle_durations`` call.  Evaluation is batched too: each eval
point vmaps ``engine.eval_one`` over the cohort (one dispatch per
batch-shape group — see ``engine.eval_many``).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config import ExperimentConfig
from repro.data.partition import ClientDataset, sample_triplet_many
from repro.fl.engine import SimulationEngine, ensure_engine
from repro.fl.scenario import DRIFT, FLASH, JOIN, LEAVE, make_scenario
from repro.obs import trace as obs
from repro.obs.recorder import SCHEMA, RoundRecorder
from repro.utils.metrics import MetricsLogger
from repro.wireless.channel import noise_w_per_hz, pathloss_pow
from repro.wireless.timing import compute_times, model_bits, upload_times

# max doubles one fading-draw block may materialise (~8 MB)
FADING_BLOCK = 1 << 20


@dataclass
class SimResult:
    name: str
    times: np.ndarray            # wall-clock at each eval point [s]
    losses: np.ndarray           # personalized (PFL) eval loss
    global_losses: np.ndarray    # loss of the raw global model
    accs: np.ndarray             # accuracy if the task defines one (else nan)
    rounds: np.ndarray           # round index at each eval point
    total_time: float
    pi: np.ndarray               # realised schedule matrix
    eta_target: np.ndarray
    eta_realised: np.ndarray
    wait_fraction: float         # mean fraction of time UEs spent idle
    payload_dispatches: int = 0  # device dispatches issued by the engine
    payloads_computed: int = 0   # payloads those dispatches produced
    # mobile multi-cell extension (zeros on the static single-cell path)
    n_cells: int = 1
    handovers: int = 0           # nearest-BS re-associations during the run
    cloud_rounds: int = 0        # hierarchical cloud merges performed
    departed_arrivals: int = 0   # uploads that arrived after a handover
    # open-world scenario extension (zeros on closed-world runs)
    ue_joins: int = 0            # Poisson arrivals activated mid-run
    ue_departures: int = 0       # departures (in-flight work epoch-cancelled)
    label_drifts: int = 0        # per-UE label-drift events applied
    # rounds still holding uploads when the event heap ran dry before the
    # round target was met (silent loss before; now counted + warned)
    aborted_rounds: int = 0
    pending_uploads: int = 0     # uploads those aborted rounds were holding
    # end-of-run telemetry summary (None unless the run was traced):
    # per-phase host seconds, device seconds, counters, per-cell arrivals,
    # and the JSONL trace path when one was written — see obs/recorder.py
    telemetry: Optional[Dict[str, Any]] = None


class TopologyAdapter:
    """What differs between the static and mobile event loops.

    The driver owns the heap, epoch cancellation, drain batching, dispatch
    decisions, eval cadence, batched requeue pricing, and ``SimResult``
    assembly; the adapter supplies topology (network geometry, bandwidth,
    cells) and protocol (the server or server hierarchy).

    Attributes the driver reads:

    ``net``  — ``EdgeNetwork``-compatible channel API (``sample_fading_batch``
               / ``distances`` / ``cpu_freq``).
    ``eta``  — participation targets (reported in ``SimResult``).
    ``bw``   — per-UE bandwidth [Hz]; may be updated **in place** by
               ``pre_requeue`` (the driver holds the array reference).
    ``n_protocol_cells`` — number of cells the drain bookkeeping tracks
               (1 for a single global server, even over many radio cells).
    """

    net: Any
    eta: np.ndarray
    bw: np.ndarray
    n_protocol_cells: int = 1

    # --- protocol ------------------------------------------------------
    def make_servers(self, params0: Any) -> None:
        raise NotImplementedError

    def rounds_done(self) -> int:
        raise NotImplementedError

    def need(self, cell: int) -> int:
        """Arrivals until ``cell``'s round closes (A − pending)."""
        raise NotImplementedError

    def participants(self, cell: int) -> int:
        """``cell``'s A (round size) — the fused-path batch target."""
        raise NotImplementedError

    def on_arrival(self, cell: int, ue: int,
                   payload: Any) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def on_arrival_batch(self, cells: np.ndarray, ues: np.ndarray,
                         payloads: Any) -> Optional[Dict[str, Any]]:
        """Batch-wise feed: one drained batch, payloads STACKED (leading
        lane axis, arrival order).  At most one round closes — on the
        last lane (drain invariant) — and its result dict is returned."""
        raise NotImplementedError

    def on_round_batch(self, cell: int, ues: List[int],
                       aggregate_fn: Callable) -> Dict[str, Any]:
        raise NotImplementedError

    def protocol(self) -> Any:
        """The top-level protocol object (``params`` / ``pi_matrix`` /
        ``realised_eta``)."""
        raise NotImplementedError

    def pending_uploads(self) -> int:
        """Uploads held toward rounds that have not closed yet."""
        p = self.protocol()
        return int(p.pending_uploads()) if hasattr(p, "pending_uploads") \
            else 0

    def open_rounds(self) -> int:
        """Rounds currently holding at least one pending upload."""
        p = self.protocol()
        if hasattr(p, "open_rounds"):
            return int(p.open_rounds())
        return 1 if self.pending_uploads() > 0 else 0

    # --- open-world scenario hooks (closed world: all no-ops) ----------
    def bind_active(self, mask: np.ndarray) -> None:
        """Receive the scenario's live activity mask BEFORE
        ``make_servers`` — initial membership, round sizes and bandwidth
        must see only the UEs active at t=0.  The array is shared: the
        scenario runtime flips bits in place as UEs join/leave."""

    def pre_drain(self) -> None:
        """Called once before every drain.  Adapters that clamp round
        sizes to live membership push the caps HERE — never mid-drain, so
        ``need`` stays constant while a drain is in flight (the drain
        invariant: at most one round closes, on the last lane)."""

    def flush_ready(self) -> List[Dict[str, Any]]:
        """Round results for every open round whose (live-cap-clamped)
        target its pending uploads already meet — churn can lower a
        target to the pending count after those uploads arrived, and no
        future arrival exists to close such a round through the ordinary
        path.  Called right after ``pre_drain``; closed world: none."""
        return []

    def on_join(self, ue: int) -> Any:
        """A dormant UE joins (scenario arrival): activate it in the
        topology/protocol and return the model params it starts from."""
        return self.protocol().params

    def on_leave(self, ue: int) -> None:
        """An active UE departs: deactivate it everywhere.  The driver
        has already epoch-cancelled its in-flight upload."""

    def on_flash(self, idx: np.ndarray,
                 rng: np.random.Generator) -> int:
        """Flash-crowd window opens: retarget ``idx`` toward the hotspot
        (mobility-model permitting).  Returns how many UEs were
        retargeted."""
        return 0

    def cell_membership(self) -> Optional[List[int]]:
        """Live per-protocol-cell membership counts for trace records
        (``None`` → the recorder omits the field)."""
        return None

    # --- topology hooks (static topology: all no-ops) ------------------
    def bind_link_budget(self, z_bits: float, d_i: np.ndarray) -> None:
        """Called once by ``make_cycle_duration_fn`` with the payload size
        Z [bits] and per-UE sample counts — the link-budget inputs a
        Theorem-2 (equal-finish) bandwidth policy needs to price compute
        times.  Adapters whose allocation ignores Z (equal split /
        weighted-equal-rate) leave this a no-op."""

    def dispatch_cell(self, ue: int) -> int:
        """Cell stamped on a cycle's heap event at dispatch time; arrivals
        are routed back to this cell even if the UE hands over while the
        upload is in flight."""
        return 0

    def dispatch_cells(self, ues: np.ndarray) -> np.ndarray:
        """Vectorized ``dispatch_cell`` — the driver stamps whole
        requeues (and checks whole drains for mid-flight handovers) in
        one call instead of one python call per UE."""
        return np.zeros(len(ues), dtype=np.int64)

    def advance_to(self, t: float) -> None:
        """Move simulated time forward (mobility, handovers, bookkeeping)."""

    def pre_requeue(self, ues) -> None:
        """Chance to refresh per-UE bandwidth before pricing new cycles."""

    def result_extras(self) -> Dict[str, Any]:
        """Extra ``SimResult`` fields (cells / handovers / cloud merges)."""
        return {}


def make_cycle_duration_fn(adapter: TopologyAdapter, wl, z_bits: float,
                           d_i: np.ndarray) -> Callable[[Any], np.ndarray]:
    """Batched requeue pricing: ONE fading draw + vectorized Eq. (10)–(11).

    The legacy drivers priced each requeued UE alone — ``sample_fading()``
    draws the whole [n] Rayleigh vector, then a ``UEChannel`` and
    python-scalar timing math, per UE per requeue.  Here a requeue of k UEs
    draws one ``[k, n]`` matrix and the timing math vectorizes over the k
    lanes.  Every value is bitwise identical to the legacy loop: the batch
    draw consumes the same bitstream, and ``pathloss_pow`` keeps d^{−κ} on
    libm's scalar pow — a full cached vector on frozen topologies, per-lane
    pricing once mobility starts replacing the distances array (see
    ``_pathloss`` below).
    """
    net = adapter.net
    adapter.bind_link_budget(z_bits, d_i)
    p, kappa = wl.tx_power_w, wl.path_loss_exp
    n0 = noise_w_per_hz(wl.noise_dbm_per_hz)
    cycles = wl.cpu_cycles_per_sample
    cache: Dict[str, Any] = {"src": None, "pw": None, "volatile": False}

    def _pathloss(dists, idx: np.ndarray) -> np.ndarray:
        # Static topologies keep one distances array for the whole run →
        # build the full d^{−κ} vector once and index it forever.  Moving
        # mobility replaces the array on every movement step; a full
        # rebuild there would cost O(n) scalar pows per requeue, so on the
        # second distinct array we switch to pricing only the requeued
        # lanes (k scalar pows — exactly the legacy per-UE cost).
        if cache["src"] is dists:
            return cache["pw"][idx]
        if not cache["volatile"] and cache["src"] is None:
            cache["pw"] = pathloss_pow(dists, kappa)
            cache["src"] = dists
            return cache["pw"][idx]
        cache["volatile"] = True
        cache["src"], cache["pw"] = None, None
        # dists is the host sim clock's numpy distance matrix; asarray
        # never touches a device array here
        # simlint: disable-next=SIM202 -- host-side distance matrix
        return pathloss_pow(np.asarray(dists)[idx], kappa)

    counter_rng = getattr(wl, "rng", "legacy") == "counter"

    def _fading_lanes(idx: np.ndarray) -> np.ndarray:
        if counter_rng:
            # counter stream: O(k) lane-indexed draws — no [k, n] matrix,
            # no dependence on how the event loop batches its pricing
            return net.fading_lanes(idx)
        # legacy stream: one [k, n] draw, in row blocks of ≤ FADING_BLOCK
        # doubles: numpy Generators fill arrays from the bitstream
        # sequentially, so the blocks are bitwise the single big call —
        # without the O(k·n) peak memory (an [n, n] matrix at the initial
        # heap fill: 2 GB at 16384 UEs)
        k = len(idx)
        rows = max(1, FADING_BLOCK // max(net.n_ues, 1))
        if k <= rows:
            return net.sample_fading_batch(k)[np.arange(k), idx]
        h = np.empty(k)
        for lo in range(0, k, rows):
            hi = min(lo + rows, k)
            h[lo:hi] = net.sample_fading_batch(hi - lo)[
                np.arange(hi - lo), idx[lo:hi]]
        return h

    def cycle_durations(ues) -> np.ndarray:
        # one span per requeue (not per lane): disabled cost is a single
        # no-op context enter/exit on the batched call
        with obs.CURRENT.span("pricing"):
            adapter.pre_requeue(ues)
            # simlint: disable-next=SIM202 -- ues is a host Python list
            idx = np.asarray(ues, dtype=np.int64)
            h = _fading_lanes(idx)
            tcmp = compute_times(cycles, d_i[idx], net.cpu_freq[idx])
            q = p * h * _pathloss(net.distances, idx) / n0   # UEChannel.q
            tcom = upload_times(z_bits, adapter.bw[idx], q)
            return tcmp + tcom

    return cycle_durations


def _protocol_call(fn, *args):
    """Feed the protocol under the "protocol" phase span, with device
    attribution when the tracer blocks (segment slicing, staleness
    aggregation, cloud merges are device tree ops)."""
    tr = obs.CURRENT
    with tr.span("protocol"):
        return tr.device_call("protocol", fn, *args)


def _closing_server(adapter: TopologyAdapter, result: Dict[str, Any]):
    """The ``SemiSyncServer`` whose round just closed (read-only: the
    recorder reads its Π row / staleness snapshot)."""
    proto = adapter.protocol()
    if hasattr(proto, "cells") and "cell" in result:
        return proto.cells[result["cell"]]
    return proto


def run_event_loop(cfg: ExperimentConfig, model,
                   clients: List[ClientDataset],
                   adapter: TopologyAdapter, *,
                   algorithm: str = "perfed", mode: str = "semi",
                   max_rounds: Optional[int] = None,
                   eval_every: int = 5, eval_clients: int = 8,
                   seed: int = 0, name: Optional[str] = None,
                   verbose: bool = False,
                   payload_mode: Optional[str] = None,
                   engine: Optional[SimulationEngine] = None,
                   tracer: Optional[obs.Tracer] = None,
                   trace_dir: Optional[str] = None,
                   profile_dir: Optional[str] = None,
                   reporter: Optional[obs.Reporter] = None) -> SimResult:
    """Run the event loop, optionally under the telemetry layer.

    ``tracer``/``trace_dir``/``profile_dir``/``reporter`` override the
    corresponding ``cfg.obs`` fields; a tracer (explicit or implied by
    ``cfg.obs.trace`` / a trace dir) is installed as the process-wide
    ``obs.trace.CURRENT`` for the duration of the run, a per-round JSONL
    trace is written when a directory is given, and the end-of-run
    summary lands on ``SimResult.telemetry``.  Tracing is read-only —
    trajectories are bitwise identical with it on or off.
    """
    oc = cfg.obs
    trace_dir = trace_dir or (oc.trace_dir or None)
    profile_dir = profile_dir or (oc.profile_dir or None)
    if tracer is None and (oc.trace or trace_dir or profile_dir):
        tracer = obs.Tracer(device=oc.device_timing,
                            profile=bool(profile_dir))
    rep = reporter or obs.Reporter("progress" if verbose else oc.report)
    with obs.use(tracer), obs.profile_trace(profile_dir):
        return _event_loop(cfg, model, clients, adapter,
                           algorithm=algorithm, mode=mode,
                           max_rounds=max_rounds, eval_every=eval_every,
                           eval_clients=eval_clients, seed=seed, name=name,
                           payload_mode=payload_mode, engine=engine,
                           tracer=tracer, trace_dir=trace_dir, rep=rep)


def _event_loop(cfg: ExperimentConfig, model,
                clients: List[ClientDataset],
                adapter: TopologyAdapter, *,
                algorithm: str, mode: str,
                max_rounds: Optional[int],
                eval_every: int, eval_clients: int,
                seed: int, name: Optional[str],
                payload_mode: Optional[str],
                engine: Optional[SimulationEngine],
                tracer: Optional[obs.Tracer],
                trace_dir: Optional[str],
                rep: obs.Reporter) -> SimResult:
    fl, wl = cfg.fl, cfg.wireless
    n = len(clients)
    max_rounds = max_rounds or fl.rounds
    rng = np.random.default_rng(seed)
    # one independent key per consumer (init / payloads / evals)
    init_key, payload_key, eval_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)

    # --- model / engine -----------------------------------------------------
    params0 = model.init(init_key)
    z_bits = wl.grad_bits or model_bits(params0, wl.bits_per_param)
    engine = ensure_engine(engine, model, fl, algorithm=algorithm,
                           payload_mode=payload_mode)
    # snapshot so SimResult reports THIS run's dispatch counts even when the
    # engine (and its lifetime counters) is shared across a sweep
    disp0, pay0 = engine.dispatches, engine.payloads_computed

    recorder: Optional[RoundRecorder] = None
    if tracer is not None:
        logger = None
        if trace_dir:
            logger = MetricsLogger(trace_dir, meta={
                "schema": SCHEMA, "name": name or f"{algorithm}-{mode}",
                "algorithm": algorithm, "mode": mode, "seed": seed,
                "n_ues": n, "payload_mode": engine.payload_mode,
                "device_timing": tracer.device_timing})
        recorder = RoundRecorder(tracer, engine=engine, logger=logger)
    # per-UE inner learning rates α_i (paper §II-B: "easily extended to the
    # general case when UEs have diverse learning rate α_i")
    if fl.alpha_spread > 0:
        s = 1.0 + fl.alpha_spread
        alphas = fl.alpha * np.exp(rng.uniform(-np.log(s), np.log(s), size=n))
    else:
        alphas = np.full(n, fl.alpha)

    # open-world scenario (None = closed world, zero overhead): the
    # activity mask must be bound BEFORE make_servers so initial
    # membership / round sizes / bandwidth see only the t=0-active UEs
    scen = make_scenario(cfg.scenario, n, seed)
    if scen is not None:
        adapter.bind_active(scen.active)
    adapter.make_servers(params0)

    # --- per-UE state -------------------------------------------------------
    held_params: List[Any] = [params0 for _ in range(n)]
    # simlint: disable-next=SIM202 -- host list comprehension, setup only
    d_i = np.array([min(fl.inner_batch + fl.outer_batch + fl.hessian_batch,
                        len(c)) for c in clients])
    busy_time = np.zeros(n)
    # batch shapes are a pure function of the shard size; a round whose UEs
    # share one signature can take the fused path, mixed rounds fall back to
    # bucketed payloads (rule lives on ClientDataset, next to the sampler)
    batch_sig = [c.triplet_sizes(fl.inner_batch, fl.outer_batch,
                                 fl.hessian_batch) for c in clients]

    cycle_durations = make_cycle_duration_fn(adapter, wl, z_bits, d_i)

    # --- eval ----------------------------------------------------------------
    eval_idx = rng.choice(n, size=min(eval_clients, n), replace=False)

    def evaluate(params, k: int) -> Tuple[float, float, float]:
        # per-client keys derived exactly as the sequential loop did, then
        # the whole cohort evaluates as one vmapped dispatch per shape
        # group (engine.eval_many); singleton groups ride the eval_one jit
        with obs.CURRENT.span("eval"):
            return _evaluate(params, k)

    def _evaluate(params, k: int) -> Tuple[float, float, float]:
        r = jax.random.fold_in(eval_key, k)
        subs, batches_list = [], []
        for ci in eval_idx:
            c = clients[ci]
            r, sub = jax.random.split(r)
            subs.append(sub)
            batches_list.append({"inner": c.sample(fl.inner_batch),
                                 "outer": {k2: v for k2, v in c.test.items()}})
        pl, gl, ac = engine.eval_many(params, batches_list, subs)
        acc = (float(np.nanmean(ac))
               if np.any(np.isfinite(ac)) else float("nan"))
        return float(np.mean(pl)), float(np.mean(gl)), acc

    # --- event loop ----------------------------------------------------------
    # epoch-based lazy cancellation: when the server re-distributes to a UE
    # whose upload is still in flight (τ > S forced refresh, Alg. 1 line 13),
    # the UE ABANDONS the stale computation and restarts — the old event is
    # dropped at pop time if its epoch is outdated.
    # event = (t_finish, seq, ue, version, duration, epoch, dispatch_cell)
    epoch = np.zeros(n, dtype=np.int64)
    # only t=0-active UEs get an initial cycle; the dormant pool is what
    # scenario arrivals later activate (closed world: everyone)
    fill_ues = np.arange(n) if scen is None else np.nonzero(scen.active)[0]
    fill_cells = adapter.dispatch_cells(fill_ues)
    # events are totally ordered by (t, seq), so heapify yields the exact
    # pop sequence of n pushes at a fraction of the fill cost
    heap: List[Tuple[float, int, int, int, float, int, int]] = [
        (float(dur), i, int(ue), 0, float(dur), 0, int(c))
        for i, (ue, dur, c) in enumerate(zip(fill_ues,
                                             cycle_durations(fill_ues),
                                             fill_cells))]
    heapq.heapify(heap)
    seq = len(fill_ues)

    times, plosses, glosses, accs, rounds_at = [], [], [], [], []
    t_now = 0.0
    do_eval = eval_every > 0            # 0 → pure-throughput mode, no evals

    if do_eval:
        p0, g0, a0 = evaluate(params0, 0)
        times.append(0.0)
        plosses.append(p0)
        glosses.append(g0)
        accs.append(a0)
        rounds_at.append(0)

    def restart_departed(items: List[Tuple[int, float]]) -> None:
        # Liveness for handed-over UEs: an upload that closed at the SOURCE
        # cell gets no redistribution from it (the UE is no longer a
        # member), and the destination owes it nothing until the τ > S
        # forced refresh — so the device simply continues from the model it
        # already holds.  Its true staleness was grafted onto the
        # destination's round clock at handover time, so the next upload is
        # weighted correctly there.  Without this the UE would idle for up
        # to S destination rounds after every mid-flight handover.
        # ``items`` is every (ue, cycle start time) of the drain batch —
        # priced with ONE cycle_durations call (one [k, n] fading draw)
        # instead of one [1, n] draw each.  A departed UE the closing
        # (destination) cell redistributed to in this very drain already
        # holds a fresh cycle — restarting it too would double-queue it.
        nonlocal seq
        items = [it for it in items if it[0] not in redistributed]
        if scen is not None:
            # a UE that departed mid-flight gets no fresh cycle: its
            # already-finished upload may still aggregate (stale-tolerant
            # protocol), but restarting it would resurrect a zombie that
            # keeps computing after it left the system
            items = [it for it in items if scen.active[it[0]]]
        if not items:
            return
        with obs.CURRENT.span("restart"):
            obs.CURRENT.add("driver.restarted_ues", len(items))
            cells_r = adapter.dispatch_cells([u for u, _ in items])
            durs_r = cycle_durations([u for u, _ in items])
            version = adapter.rounds_done()
            for (ue, t0), dur, dc in zip(items, durs_r, cells_r):
                heapq.heappush(heap, (t0 + float(dur), seq, ue, version,
                                      float(dur), int(epoch[ue]), int(dc)))
                seq += 1

    redistributed: set = set()          # UEs given a new cycle this drain

    def apply_scenario_event(ev: Tuple[float, str, int]) -> bool:
        """One open-world lifecycle event, in simulated-time order with
        the heap.  Joins are priced and queued like any other cycle;
        leaves cancel in-flight work via the epoch mechanism (exactly the
        τ > S refresh path); drift rewrites the client's labels; flash
        retargets waypoints at the hotspot.  Returns True when the event
        changed membership — the caller must then end its drain so the
        live-membership round caps can re-arm (``pre_drain``/``flush``)
        before any further pops."""
        nonlocal seq
        t_ev, kind, ue = ev
        adapter.advance_to(t_ev)
        if kind == JOIN:
            # a joining UE starts from the model its cell would hand it,
            # with a fresh cycle priced through the ordinary batched path
            held_params[ue] = adapter.on_join(ue)
            epoch[ue] += 1              # orphan any stray old event
            obs.CURRENT.add("driver.ue_joins")
            dc = int(adapter.dispatch_cells([ue])[0])
            dur = float(cycle_durations([ue])[0])
            heapq.heappush(heap, (t_ev + dur, seq, ue,
                                  adapter.rounds_done(), dur,
                                  int(epoch[ue]), dc))
            seq += 1
            return True
        if kind == LEAVE:
            epoch[ue] += 1              # lazy-cancel the in-flight upload
            adapter.on_leave(ue)
            obs.CURRENT.add("driver.ue_departures")
            return True
        if kind == DRIFT:
            changed = clients[ue].drift_labels(scen.rng,
                                               cfg.scenario.drift_frac)
            obs.CURRENT.add("driver.label_drifts")
            if changed:
                obs.CURRENT.add("driver.drifted_samples", changed)
        elif kind == FLASH:
            moved = adapter.on_flash(scen.hotspot_targets(), scen.rng)
            if moved:
                obs.CURRENT.add("driver.flash_retargets", moved)
        return False

    def handle(result) -> None:
        nonlocal seq
        if recorder is not None:
            # read-only peek at the closing server: its just-appended Π row
            # is the arrived-UE set, its staleness vector the τ snapshot
            srv = _closing_server(adapter, result)
            rec = recorder.on_round(
                result=result,
                ues=np.nonzero(srv.history_pi[-1])[0],
                heap_depth=len(heap),
                extras=adapter.result_extras(),
                t_sim=t_now,
                staleness=srv.history_staleness[-1],
                members=adapter.cell_membership())
            rep.debug(f"[trace] round {rec['round']} cell={rec['cell']} "
                      f"a={rec['a']} heap={rec['heap_depth']} "
                      f"wall={rec['wall_s']*1e3:.1f}ms")
        dist = result["distribute"]
        if dist:
            with obs.CURRENT.span("redistribute"):
                redistributed.update(int(i) for i in dist)
                for i in dist:
                    held_params[i] = result["params"]
                # simlint: disable-next=SIM202 -- dist is a host int list
                dist_arr = np.asarray(dist, dtype=np.int64)
                epoch[dist_arr] += 1    # cancels any in-flight computation
                cells_d = adapter.dispatch_cells(dist_arr)
                for i, dur_i, dc in zip(dist, cycle_durations(dist),
                                        cells_d):
                    heapq.heappush(heap, (t_now + float(dur_i), seq, int(i),
                                          result["round"], float(dur_i),
                                          int(epoch[i]), int(dc)))
                    seq += 1
        k = result["round"]
        if do_eval and (k % eval_every == 0 or k == max_rounds):
            p, g, a = evaluate(result["params"], k)
            times.append(t_now)
            plosses.append(p)
            glosses.append(g)
            accs.append(a)
            rounds_at.append(k)
            cell = f" cell={result['cell']}" if "cell" in result else ""
            rep.progress(f"[{name or algorithm}-{mode}]{cell} round {k:4d} "
                         f"t={t_now:8.2f}s ploss={p:.4f} gloss={g:.4f}")

    inf = float("inf")

    def events_remain() -> bool:
        # a dry heap can only be refilled by a future join (can_spawn);
        # departures/drift alone cannot restart progress
        return bool(heap) or (scen is not None and scen.can_spawn())

    while adapter.rounds_done() < max_rounds and events_remain():
        # live-membership round-size caps are pushed between drains only
        # (never mid-drain): ``need`` stays constant while a drain is in
        # flight, preserving the drain invariant
        adapter.pre_drain()
        # a clamped target the pending uploads already meet can never be
        # closed by a future arrival (every remaining member's upload is
        # in) — close those rounds now, then re-arm the caps: the closes
        # redistribute, changing both pending and in-flight counts
        flushed = adapter.flush_ready()
        if flushed:
            for result in flushed:
                handle(result)
                if adapter.rounds_done() >= max_rounds:
                    break
            continue
        # ---- drain arrivals until the first cell would close its round ----
        # No distribution (hence no cancellation, no membership effect on
        # queued events) can occur before then, so every drained payload is
        # computable NOW, as one batch — per cell.  ``need`` is recomputed
        # per pop: it depends only on pending-upload counts, which change
        # exclusively when arrivals are *fed* (after the drain), never on
        # mid-drain handovers — recomputing makes the loop robust to future
        # protocols where that invariant stops holding, at O(1) cost.
        drained = [0] * adapter.n_protocol_cells
        batch: List[Tuple[float, int, int, float, int]] = []
        closing: Optional[int] = None
        redistributed.clear()
        stale_pops = 0
        rearm = False       # drain ended on a membership change
        # NOTE: the pop loop itself carries no per-pop tracing calls — the
        # drain is the hot path and must stay free when tracing is off;
        # mobility/handover time is attributed inside the (rare) tick
        # branch of ``multicell.advance_to``, not here.  Scenario lifecycle
        # events are interleaved in simulated-time order: each one is
        # applied before any later-timestamped upload pops, so a departure
        # always cancels in-flight work before that work could arrive.
        with obs.CURRENT.span("drain"):
            while True:
                if not heap and (scen is None or not scen.can_spawn()):
                    break
                t_head = heap[0][0] if heap else inf
                if scen is not None and scen.next_time() <= t_head:
                    ev = scen.next_event(t_head)
                    if ev is not None and apply_scenario_event(ev):
                        # membership changed: end the drain so the live
                        # caps re-arm (pre_drain / flush_ready) before
                        # any further pops — mid-drain cap pushes would
                        # break the drain invariant instead
                        rearm = True
                        break
                    continue
                if not heap:
                    break
                t, sq, ue, _version, dur, ev_epoch, cell = \
                    heapq.heappop(heap)
                if ev_epoch != epoch[ue]:
                    stale_pops += 1
                    continue            # abandoned (stale-refresh) cycle
                adapter.advance_to(t)
                # route by the *stamped* dispatch cell: an upload in flight
                # across a handover still closes the round it was computed
                # for
                batch.append((t, ue, sq, dur, cell))
                drained[cell] += 1
                if drained[cell] >= adapter.need(cell):
                    closing = cell
                    break
        if stale_pops:
            obs.CURRENT.add("driver.stale_pops", stale_pops)
        if not batch:
            if rearm:
                continue    # nothing drained yet; re-clamp and go again
            break

        held = [held_params[ue] for _, ue, _, _, _ in batch]
        a_i = [alphas[ue] for _, ue, _, _, _ in batch]
        ues_arr = np.fromiter((b[1] for b in batch), np.int64,
                              count=len(batch))
        cells_arr = np.fromiter((b[4] for b in batch), np.int64,
                                count=len(batch))

        srv_a = adapter.participants(closing) if closing is not None else -1
        if (engine.payload_mode == "batched" and len(batch) == srv_a
                and srv_a <= engine.max_bucket
                and all(b[4] == closing for b in batch)
                and len({batch_sig[ue] for ue in ues_arr}) == 1):
            # fused fast path: the whole round of the closing cell — per-
            # arrival RNG, vmapped payloads, Eq. (8) stale aggregation —
            # fuses into one device dispatch per model-version group
            obs.CURRENT.add("driver.rounds_fused")
            with obs.CURRENT.span("sampling"):
                triplets = [clients[ue].sample_triplet(
                    fl.inner_batch, fl.outer_batch, fl.hessian_batch)
                    for ue in ues_arr]
            t_now = batch[-1][0]
            busy_time[ues_arr] += [b[3] for b in batch]   # completed cycles

            def aggregate(params, weights):
                return engine.round_update(
                    params, held, triplets,
                    [sq for _, _, sq, _, _ in batch],
                    a_i, weights, beta=fl.beta, base_key=payload_key)

            handle(_protocol_call(adapter.on_round_batch,
                                  closing, [int(ue) for ue in ues_arr],
                                  aggregate))
            moved = np.nonzero(
                adapter.dispatch_cells(ues_arr) != cells_arr)[0]
            restart_departed([(int(ues_arr[i]), batch[i][0])
                              for i in moved])
        elif engine.payload_mode == "sequential":
            obs.CURRENT.add("driver.rounds_sequential")
            with obs.CURRENT.span("sampling"):
                triplets = [clients[ue].sample_triplet(
                    fl.inner_batch, fl.outer_batch, fl.hessian_batch)
                    for ue in ues_arr]
            with obs.CURRENT.span("payload"):
                payloads = engine.compute_payloads(
                    held, triplets,
                    [jax.random.fold_in(payload_key, sq)
                     for _, _, sq, _, _ in batch],
                    a_i)
            # ---- feed the protocol in arrival order ------------------------
            restarts: List[Tuple[int, float]] = []
            for (t, ue, _sq, dur, cell), payload in zip(batch, payloads):
                t_now = t
                busy_time[ue] += dur    # only completed cycles count as busy
                result = _protocol_call(adapter.on_arrival, cell, ue,
                                        payload)
                if result is not None:
                    handle(result)
                if adapter.dispatch_cell(ue) != cell:
                    restarts.append((ue, t))
            restart_departed(restarts)
        else:
            # ---- batch-wise feed: payloads stay stacked on device ----------
            # lanes grouped by batch-shape signature; each group samples its
            # triplets STACKED (one RNG draw + gather per client — bitwise
            # the per-UE loop, the generators are private) and the engine
            # returns ONE stacked payload tree that goes to the protocol
            # whole: no per-lane tree.map extraction, no per-arrival
            # on_arrival python loop
            t_now = batch[-1][0]
            orig_pos = None
            sig_of = [batch_sig[ue] for ue in ues_arr]
            cell_sorted = closing is not None and adapter.n_protocol_cells > 1
            if cell_sorted:
                # sort lanes by (cell, signature), stable, closing cell
                # LAST: the hierarchy slices per-cell segments out of the
                # stacked payloads contiguously, and each cell×signature
                # run is one contiguous engine group — no whole-tree
                # gather or inverse permute anywhere (payload trees are
                # [k, model]-sized, so every avoided copy counts).  Within
                # a (cell, signature) run arrival order is preserved;
                # summation order changes only for a cell with mixed
                # signatures (tolerance-level, never golden-pinned)
                cell_keys = np.where(cells_arr == closing,
                                     np.iinfo(np.int64).max, cells_arr)
                sig_ids: Dict[Tuple, int] = {}
                sig_rank = np.fromiter(
                    (sig_ids.setdefault(s, len(sig_ids)) for s in sig_of),
                    np.int64, count=len(sig_of))
                perm = np.lexsort((sig_rank, cell_keys))
                if not np.array_equal(perm, np.arange(len(batch))):
                    orig_pos = perm
                    batch = [batch[i] for i in perm]
                    ues_arr = ues_arr[perm]
                    cells_arr = cells_arr[perm]
                    held = [held[i] for i in perm]
                    a_i = [a_i[i] for i in perm]
                    sig_of = [sig_of[i] for i in perm]
            if cell_sorted:
                # contiguous runs of equal signature, in feed order
                lane_groups: List[List[int]] = []
                start = 0
                for i in range(1, len(sig_of) + 1):
                    if i == len(sig_of) or sig_of[i] != sig_of[start]:
                        lane_groups.append(list(range(start, i)))
                        start = i
            else:
                sig_groups: Dict[Tuple, List[int]] = {}
                for lane, s in enumerate(sig_of):
                    sig_groups.setdefault(s, []).append(lane)
                lane_groups = list(sig_groups.values())
            obs.CURRENT.add("driver.rounds_batchwise")
            with obs.CURRENT.span("sampling"):
                groups = [(lanes, sample_triplet_many(
                               [clients[int(ues_arr[i])] for i in lanes],
                               fl.inner_batch, fl.outer_batch,
                               fl.hessian_batch))
                          for lanes in lane_groups]
            with obs.CURRENT.span("payload"):
                payloads_stacked = engine.compute_payloads_stacked(
                    held, groups, [sq for _, _, sq, _, _ in batch], a_i,
                    payload_key)
            busy_time[ues_arr] += [b[3] for b in batch]   # completed cycles
            result = _protocol_call(adapter.on_arrival_batch, cells_arr,
                                    ues_arr, payloads_stacked)
            if result is not None:
                handle(result)
            moved = np.nonzero(
                adapter.dispatch_cells(ues_arr) != cells_arr)[0]
            if orig_pos is not None:
                # restarts price fading in list order — restore the drain
                # arrival order the per-arrival path uses
                moved = moved[np.argsort(orig_pos[moved])]
            restart_departed([(int(ues_arr[i]), batch[i][0])
                              for i in moved])

    # drain the async dispatch queue so wall-clock timings of this function
    # include all device work it issued (jit dispatch is asynchronous)
    proto = adapter.protocol()
    jax.block_until_ready(jax.tree.leaves(proto.params))

    # ---- aborted-round accounting -----------------------------------------
    # An exit BEFORE the round target with uploads still pending means the
    # event heap ran dry mid-round (e.g. A > live population, or a frozen
    # per-cell A above a shrunken cell's membership).  This used to be
    # silent — the run reported a clean SimResult and the held uploads
    # simply vanished.  Count it, warn, and surface it on the result.
    pending = adapter.pending_uploads()
    aborted = adapter.open_rounds() \
        if (adapter.rounds_done() < max_rounds and pending > 0) else 0
    if aborted:
        obs.CURRENT.add("driver.aborted_round", aborted)
        rep.warn(f"[{name or f'{algorithm}-{mode}'}] event heap exhausted "
                 f"with {pending} pending upload(s) across {aborted} open "
                 f"round(s) — completed {adapter.rounds_done()}/"
                 f"{max_rounds} rounds")

    telemetry = None
    if recorder is not None:
        scen_extras = {} if scen is None else {
            "ue_joins": scen.ue_joins, "ue_departures": scen.ue_departures,
            "label_drifts": scen.label_drifts}
        telemetry = recorder.finalize(extras={
            **{k: v for k, v in adapter.result_extras().items()
               if isinstance(v, (int, np.integer))},
            **scen_extras,
            **({"aborted_rounds": aborted} if aborted else {})})

    # busy time over seconds of *existence*: a departed UE's absence is
    # not idle time (the closed-world denominator n·t_now is reproduced
    # exactly by alive_total when no churn events fired)
    alive_s = scen.alive_total(t_now) if scen is not None else n * t_now
    wait_frac = float(1.0 - busy_time.sum() / max(alive_s, 1e-9))
    return SimResult(
        ue_joins=scen.ue_joins if scen is not None else 0,
        ue_departures=scen.ue_departures if scen is not None else 0,
        label_drifts=scen.label_drifts if scen is not None else 0,
        aborted_rounds=aborted,
        pending_uploads=pending,
        telemetry=telemetry,
        name=name or f"{algorithm}-{mode}",
        # simlint: disable-next=SIM202 -- final result assembly, host lists
        times=np.array(times), losses=np.array(plosses),
        # simlint: disable-next=SIM202 -- final result assembly, host lists
        global_losses=np.array(glosses), accs=np.array(accs),
        # simlint: disable-next=SIM202 -- final result assembly, host lists
        rounds=np.array(rounds_at), total_time=t_now,
        pi=proto.pi_matrix(), eta_target=adapter.eta,
        eta_realised=proto.realised_eta(),
        wait_fraction=max(wait_frac, 0.0),
        payload_dispatches=engine.dispatches - disp0,
        payloads_computed=engine.payloads_computed - pay0,
        **adapter.result_extras(),
    )
