"""Client-side local computation for the three algorithm families.

A client receives the global model ``w`` and produces a *payload* — a
pytree with the same structure as the params — which the server plugs into
Eq. (8):  w ← w − β/A · Σ payloads.

* ``perfed``  — the paper's Eq. (7) meta-gradient (3 independent batches,
  exact HVP term, optional first-order variant).
* ``fedavg``  — E local epochs of SGD; payload = (w − w_local)/λ (pseudo-
  gradient form so sync/semi/async share the same server rule).
* ``fedprox`` — like fedavg but local objective + (μ/2)‖w − w_global‖².
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import FLConfig
from repro.core import perfed
from repro.utils import tree_scale, tree_sub

PayloadFn = Callable[..., Any]    # (params, batches, rng) -> payload pytree


def _scalar_loss(model, params, batch, rng):
    out = model.loss(params, batch, rng)
    return out[0] if isinstance(out, tuple) else out


def _local_sgd(model, params, batches, lr: float, steps: int, rng,
               prox_mu: float = 0.0):
    """``steps`` SGD steps over the provided batch list (cycled)."""
    anchor = params

    def one_step(p, inp):
        batch, r = inp
        def obj(q):
            loss = _scalar_loss(model, q, batch, r)
            if prox_mu > 0.0:
                sq = jax.tree.map(lambda a, b: jnp.sum(
                    jnp.square((a - b).astype(jnp.float32))), q, anchor)
                loss = loss + 0.5 * prox_mu * jax.tree.reduce(
                    jnp.add, sq, jnp.asarray(0.0))
            return loss
        g = jax.grad(obj)(p)
        return jax.tree.map(lambda a, b: (a - lr * b).astype(a.dtype), p, g), 0

    rngs = jax.random.split(rng, steps)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches) \
        if len(batches) > 1 else jax.tree.map(lambda x: x[None], batches[0])
    n_b = jax.tree.leaves(stacked)[0].shape[0]
    idx = jnp.arange(steps) % n_b
    seq = jax.tree.map(lambda x: x[idx], stacked)
    p_final, _ = jax.lax.scan(one_step, params, (seq, rngs))
    return p_final


def make_payload_fn(model, fl: FLConfig, algorithm: str, *,
                    jit: bool = True) -> PayloadFn:
    """Payload computation for one client.

    ``alpha`` is a traced argument so heterogeneous per-UE learning rates
    α_i (the paper's §II-B generalisation) share one compiled function.
    ``jit=False`` returns the raw traceable function — the batched engine
    wraps it in ``vmap`` itself and jits per bucket size.
    """

    if algorithm == "perfed":
        def payload(params, batches, rng, alpha):
            return perfed.perfed_grad(model.loss, params, batches, alpha,
                                      first_order=fl.first_order, rng=rng)
    elif algorithm in ("fedavg", "fedprox"):
        mu = fl.prox_mu if algorithm == "fedprox" else 0.0
        steps = max(1, fl.local_epochs)

        def payload(params, batches, rng, alpha):
            blist = [batches["inner"], batches["outer"], batches["hessian"]]
            w_local = _local_sgd(model, params, blist, alpha, steps, rng,
                                 prox_mu=mu)
            # pseudo-gradient: Δ/α̂ so the server's β-scaled rule matches SGD
            return tree_scale(tree_sub(params, w_local),
                              1.0 / (alpha * steps))
    elif algorithm == "pfedme":
        # pFedMe [Dinh et al., ref 11]: personalized model θ̂ solves
        # min_θ f_i(θ) + λ/2‖θ − w‖²; the Moreau-envelope gradient
        # ∇F_i(w) = λ(w − θ̂(w)) is the upload
        lam = fl.pfedme_lambda
        steps = max(1, fl.pfedme_steps)

        def payload(params, batches, rng, alpha):
            blist = [batches["inner"], batches["outer"], batches["hessian"]]
            theta = _local_sgd(model, params, blist, alpha, steps, rng,
                               prox_mu=lam)
            return tree_scale(tree_sub(params, theta), lam)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    return jax.jit(payload) if jit else payload


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def personalized_eval(model, fl: FLConfig, params, client_batches, rng=None):
    """PFL metric: adapt on the client's support batch, evaluate on its
    held-out query batch.  Returns (loss, maybe-accuracy)."""
    adapted = perfed.adapt(model.loss, params, client_batches["inner"],
                           fl.alpha, rng)
    out = model.loss(adapted, client_batches["outer"], rng)
    return out if isinstance(out, tuple) else (out, {})


def global_eval(model, params, batch, rng=None):
    out = model.loss(params, batch, rng)
    return out if isinstance(out, tuple) else (out, {})
