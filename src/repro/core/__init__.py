# The paper's primary contribution: PerFedS² — semi-synchronous
# personalized federated averaging with joint bandwidth allocation + UE
# scheduling.
from repro.core.bandwidth import lambertw, optimal_bandwidth
from repro.core.convergence import fosp_bound, step_condition
from repro.core.perfed import (
    adapt,
    perfed_grad,
    perfed_grad_exact,
    perfed_loss,
)
from repro.core.scheduler import (
    estimate_A_K,
    greedy_schedule,
    relative_frequencies,
)

__all__ = [
    "adapt",
    "estimate_A_K",
    "fosp_bound",
    "greedy_schedule",
    "lambertw",
    "optimal_bandwidth",
    "perfed_grad",
    "perfed_grad_exact",
    "perfed_loss",
    "relative_frequencies",
    "step_condition",
]
