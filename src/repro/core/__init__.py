# The paper's primary contribution: PerFedS² — semi-synchronous personalized
# federated averaging with joint bandwidth allocation + UE scheduling.
from repro.core.perfed import perfed_grad, perfed_loss, adapt, perfed_grad_exact
from repro.core.scheduler import greedy_schedule, relative_frequencies, estimate_A_K
from repro.core.bandwidth import optimal_bandwidth, lambertw
from repro.core.convergence import fosp_bound, step_condition
