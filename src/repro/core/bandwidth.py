"""Optimal bandwidth allocation — Sec. V-B (Theorems 2–4) of the paper.

Core facts implemented here:

* Theorem 2: within a round, the round time is minimised iff all scheduled
  UEs finish simultaneously (uplink rate is monotone in bandwidth, so any
  slack is re-assignable to the slowest UE).
* Theorem 4: the per-UE bandwidth that hits a finish time ``t`` has the
  closed form  b = −q·Γ / (W₋₁(−Γ e^{−Γ}) + Γ),  Γ = Z·N₀ /((t−Tcmp)·p·h·d^{−κ}),
  with W the Lambert-W function; any allocation between the two extreme
  policies (only-A_k vs all-UE weighted-equal-rate) attains the same optimum.

Everything is host-side numpy (the allocator runs in the round loop of the
simulator, not inside jit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.obs import trace as obs


# ---------------------------------------------------------------------------
# Lambert W (principal and -1 branches) via Halley iteration
# ---------------------------------------------------------------------------

def lambertw(x: np.ndarray, branch: int = 0, iters: int = 64) -> np.ndarray:
    """Lambert W: solves w·e^w = x. Supports branch 0 (x ≥ −1/e) and −1
    (−1/e ≤ x < 0). Vectorised, float64."""
    x = np.asarray(x, dtype=np.float64)
    if branch == 0:
        # start: series for small |x|, log asymptote for large x
        w = np.where(x >= 1.0,
                     np.log(np.maximum(x, 1e-300)),
                     x / (1.0 + np.maximum(x, -0.99)))
    elif branch == -1:
        # valid for x in [-1/e, 0)
        lx = np.log(np.maximum(-x, 1e-300))
        w = lx - np.log(np.maximum(-lx, 1e-12))
        w = np.where(x > -0.1, lx - np.log(-lx), w)
        w = np.minimum(w, -1.0)
    else:
        raise ValueError("branch must be 0 or -1")
    for _ in range(iters):
        ew = np.exp(np.clip(w, -700, 700))
        f = w * ew - x
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1 + 1e-300)
        step = f / np.where(np.abs(denom) < 1e-300, 1e-300, denom)
        w = w - step
        if branch == -1:
            w = np.minimum(w, -1.0 + 1e-12)
    return w


# ---------------------------------------------------------------------------
# Rate model (Eq. 9-10)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class UEChannel:
    """Per-UE channel snapshot in a round."""
    p: float           # transmit power [W]
    h: float           # small-scale fading coefficient (Rayleigh sample)
    dist: float        # distance to BS [m]
    kappa: float       # path loss exponent
    n0: float          # noise PSD [W/Hz]

    @property
    def q(self) -> float:
        """q ≡ p·h·d^{−κ} / N₀ — SNR numerator per Hz (units of Hz·SNR)."""
        return self.p * self.h * self.dist ** (-self.kappa) / self.n0


def uplink_rate(b: np.ndarray, ch: UEChannel) -> np.ndarray:
    """r = b · ln(1 + q / b) [nats/s]  (Eq. 9)."""
    b = np.asarray(b, dtype=np.float64)
    return b * np.log1p(ch.q / np.maximum(b, 1e-12))


def bandwidth_for_rate(rate: float, ch: UEChannel) -> float:
    """Invert Eq. 9: the bandwidth b with r(b) = rate (Theorem 4 closed form).

    With c ≡ rate/q:  b = −q·c / (W₋₁(−c·e^{−c}) + c).  Requires c < 1
    (rate below the b→∞ limit r→q); returns +inf if unattainable.
    """
    q = ch.q
    c = rate / q
    if c >= 1.0:
        return float("inf")
    if c <= 0.0:
        return 0.0
    w = float(lambertw(np.asarray(-c * np.exp(-c)), branch=-1))
    u = -w / c - 1.0          # u = q/b > 0
    if u <= 0:
        return float("inf")
    return q / u


def bandwidth_for_time(z_bits: float, t: float, tcmp: float, ch: UEChannel,
                       bits_per_nat: float = 1.0 / np.log(2.0)) -> float:
    """Bandwidth so UE finishes compute+upload of Z bits in exactly t seconds
    (Γ of Theorem 4 = Z·N₀/((t−Tcmp)·p·h·d^{−κ}) = required_rate / q)."""
    t_com = t - tcmp
    if t_com <= 0:
        return float("inf")
    rate_nats = z_bits / bits_per_nat / t_com      # required nats/s
    return bandwidth_for_rate(rate_nats, ch)


def bandwidths_for_time(z_bits: np.ndarray, t: float, tcmp: np.ndarray,
                        q: np.ndarray,
                        bits_per_nat: float = 1.0 / np.log(2.0)
                        ) -> np.ndarray:
    """Vectorized ``bandwidth_for_time`` over the UEs of one round, with
    ``q = p·h·d^{−κ}/N₀`` per UE precomputed (``UEChannel.q``).

    Every lane is **bitwise identical** to the scalar form: the expression
    applies the same float64 ufunc chain elementwise (numpy's f64 loops call
    the same libm routines the scalar path does — unlike ``pow``, see
    ``wireless.channel.pathloss_pow``), and the Lambert-W Halley iteration
    is already elementwise.  This is what makes the Theorem-2 bisection
    affordable inside the mobile loop's requeue at 1024 UEs
    (``tests/test_bandwidth_properties.py`` pins the equivalence).
    """
    z = np.asarray(z_bits, dtype=np.float64)
    tc = np.asarray(tcmp, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    t_com = t - tc
    out = np.full(len(q), np.inf)
    feas = t_com > 0
    if not np.any(feas):
        return out
    rate = (z[feas] / bits_per_nat) / t_com[feas]  # required nats/s
    c = rate / q[feas]
    b = np.full(len(rate), np.inf)
    b[c <= 0.0] = 0.0
    mid = (c > 0.0) & (c < 1.0)
    if np.any(mid):
        cm = c[mid]
        w = lambertw(-cm * np.exp(-cm), branch=-1)
        u = -w / cm - 1.0
        b[mid] = np.where(u > 0, q[feas][mid] / u, np.inf)
    out[feas] = b
    return out


# ---------------------------------------------------------------------------
# Theorem 2: equal-finish-time allocation within a round
# ---------------------------------------------------------------------------

class EqualFinishAllocation(NamedTuple):
    """Theorem-2 allocation result.

    ``converged`` is False when the bisection exhausted ``max_iter`` without
    reaching ``tol``, or when the final simplex rescale (Σb = B numerical
    guard) had to move the allocation materially — in either case the
    returned ``b`` no longer makes all UEs finish simultaneously at
    ``t_star``, and callers relying on the equal-finish property (Theorem 2)
    should widen ``max_iter``/``tol`` instead of trusting ``b`` blindly.
    The rescale used to happen silently, masking non-convergence.
    """
    b: np.ndarray
    t_star: float
    converged: bool


def equal_finish_allocation(z_bits: Sequence[float], tcmp: Sequence[float],
                            channels: Optional[Sequence[UEChannel]],
                            total_bw: float,
                            *, tol: float = 1e-9, max_iter: int = 200,
                            t_hint: Optional[float] = None,
                            q: Optional[np.ndarray] = None
                            ) -> EqualFinishAllocation:
    """Split ``total_bw`` among the scheduled UEs so all finish at the same
    time T* (Theorem 2).  Returns ``EqualFinishAllocation(b, t_star,
    converged)``.

    T ↦ Σ_i b_i(T) is strictly decreasing, so bisect on T.

    ``t_hint`` warm-starts the bracket from a previous round's ``t_star``
    (the mobile loop re-solves per cell on every membership change, and T*
    drifts slowly between requeues): a feasible hint becomes the upper
    bracket, an infeasible one the lower — either way the bisection starts
    tight instead of doubling up from ``max(tcmp)``.  ``t_hint=None`` keeps
    the cold-start bracket bit-for-bit.

    Callers that already hold the per-UE SNR numerators may pass ``q``
    (= ``UEChannel.q`` per UE, same scalar-pow path-loss convention) and
    ``channels=None`` — the mobile loop's per-requeue realloc does, to skip
    building a throwaway list of channel objects.
    """
    z = np.asarray(z_bits, dtype=np.float64)
    tc = np.asarray(tcmp, dtype=np.float64)
    if q is None:
        q = np.array([ch.q for ch in channels], dtype=np.float64)
    else:
        q = np.asarray(q, dtype=np.float64)
    n = len(q)
    assert len(z) == len(tc) == n

    def need(t: float) -> float:
        # cumsum[-1] is the same sequential left-to-right addition a
        # python ``sum`` over the scalar calls performed (np.sum's pairwise
        # reduction would differ in the last ulps), so vectorizing the
        # bisection keeps t_star bit-for-bit
        return float(np.cumsum(bandwidths_for_time(z, t, tc, q))[-1])

    lo = float(tc.max()) * (1.0 + 1e-9) + 1e-12
    hi = max(lo * 2.0, 1e-6)
    warm = t_hint is not None and np.isfinite(t_hint) and t_hint > lo
    obs.CURRENT.add("bandwidth.warm_starts" if warm
                    else "bandwidth.cold_starts")
    if warm:
        if need(float(t_hint)) > total_bw:
            lo = float(t_hint)           # T* above the hint: raise the floor
            hi = max(hi, lo * 2.0)
        else:
            hi = float(t_hint)           # T* at or below the hint: cap
    while need(hi) > total_bw and hi < 1e12:
        hi *= 2.0
    met_tol = False
    iters = 0
    for _ in range(max_iter):
        iters += 1
        mid = 0.5 * (lo + hi)
        if need(mid) > total_bw:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * max(hi, 1.0):
            met_tol = True
            break
    obs.CURRENT.add("bandwidth.bisect_iters", iters)
    t_star = hi
    b = bandwidths_for_time(z, t_star, tc, q)
    # numerical guard: scale onto the simplex Σb = B — and *say so* when the
    # scale is material (then b no longer equalises finish times at t_star)
    s = b.sum()
    rescale_ok = bool(np.isfinite(s) and s > 0
                      and abs(s - total_bw) <= 1e-6 * total_bw)
    if np.isfinite(s) and s > 0:
        b = b * (total_bw / s)
    return EqualFinishAllocation(b, t_star, met_tol and rescale_ok)


def theorem4_lower_bound(z_bits: float, t_star: float, tcmp: float,
                         ch: UEChannel, eta_i: float) -> float:
    """The Γ-form lower bound of Eq. (33) for b_k^i (paper's closed form).

    With Γ = Z·N₀/((t*−Tcmp)·p·h·d^{−κ}) = (Z/t_com)/q this is
    η_i · (−q·Γ / (W₋₁(−Γe^{−Γ}) + Γ)) = η_i · Z / (t_com · −(W+Γ)) —
    i.e. η_i times the Theorem-4 closed-form bandwidth for rate Z/t_com
    (``bandwidth_for_rate``; pinned by ``tests/test_bandwidth.py``).  An
    earlier version multiplied *and divided* by ``total_bw · n_ues``,
    carrying two dead parameters through the formula.
    """
    t_com = t_star - tcmp
    if t_com <= 0:
        return float("inf")
    gamma = z_bits * ch.n0 / (t_com * ch.p * ch.h * ch.dist ** (-ch.kappa))
    w = float(lambertw(np.asarray(-gamma * np.exp(-gamma)), branch=-1))
    denom = w + gamma
    if denom >= 0:
        return float("inf")
    return eta_i * z_bits / (t_com * (-denom))


def weighted_equal_rate_allocation(eta: Sequence[float],
                                   channels: Sequence[UEChannel],
                                   total_bw: float, *, iters: int = 100
                                   ) -> np.ndarray:
    """The other extreme of Theorem 4: all n UEs share B with rates
    r_i/η_i equalised (fixed-point on the common rate scale)."""
    eta = np.asarray(eta, dtype=np.float64)
    n = len(channels)
    b = np.full(n, total_bw / n)
    for _ in range(iters):
        # current per-unit-eta rate implied by each b_i
        r = np.array([uplink_rate(b[i], channels[i]) for i in range(n)])
        scale = r / eta
        target = np.exp(np.mean(np.log(np.maximum(scale, 1e-30))))
        b_new = np.array([bandwidth_for_rate(target * eta[i], channels[i])
                          for i in range(n)])
        if not np.all(np.isfinite(b_new)):
            b_new = np.where(np.isfinite(b_new), b_new, b)
        b_new = b_new * (total_bw / b_new.sum())
        if np.max(np.abs(b_new - b)) < 1e-9 * total_bw:
            b = b_new
            break
        b = 0.5 * b + 0.5 * b_new
    return b


def optimal_bandwidth(z_bits: Sequence[float], tcmp: Sequence[float],
                      channels: Sequence[UEChannel], total_bw: float,
                      ) -> EqualFinishAllocation:
    """Public entry: Theorem-2 equal-finish allocation for one round's
    scheduled set; returns ``EqualFinishAllocation(b, round_time,
    converged)``."""
    return equal_finish_allocation(z_bits, tcmp, channels, total_bw)


# ---------------------------------------------------------------------------
# Footnote-1 extension: transmit power as a decision variable
# ---------------------------------------------------------------------------

def power_for_time(z_bits: float, t: float, tcmp: float, bandwidth_hz: float,
                   ch: UEChannel, p_max: float = float("inf")) -> float:
    """Minimum transmit power so the UE finishes Z bits in exactly t seconds
    at fixed bandwidth b (the paper's footnote-1 generalisation: "other
    decision variables such like transmit power can also be included").

    Invert Eq. 9 in p:  r = b·ln(1 + p·g/(b·N₀))  ⇒
        p = (e^{r/b} − 1)·b·N₀ / g,   g ≡ h·d^{−κ}.
    Returns +inf (infeasible) if p > p_max or t ≤ tcmp.
    """
    t_com = t - tcmp
    if t_com <= 0 or bandwidth_hz <= 0:
        return float("inf")
    rate_nats = z_bits * np.log(2.0) / t_com
    g = ch.h * ch.dist ** (-ch.kappa)
    p = (np.exp(rate_nats / bandwidth_hz) - 1.0) * bandwidth_hz * ch.n0 / g
    return float(p) if p <= p_max else float("inf")


def min_power_equal_finish(z_bits: Sequence[float], tcmp: Sequence[float],
                           bandwidths: Sequence[float],
                           channels: Sequence[UEChannel], t_star: float
                           ) -> np.ndarray:
    """Per-UE minimum powers hitting a common finish time t* at the given
    bandwidth split — the energy-efficient counterpart of Theorem 2."""
    return np.array([
        power_for_time(z_bits[i], t_star, tcmp[i], bandwidths[i], channels[i])
        for i in range(len(channels))])
