"""Per-FedAvg meta-gradient — Eq. (3)–(7) of the paper.

The PFL objective per client is ``F_i(w) = f_i(w − α ∇f_i(w))`` (Eq. 4) and
its gradient (Eq. 5):

    ∇F_i(w) = (I − α ∇²f_i(w)) ∇f_i(w − α ∇f_i(w))

The stochastic version (Eq. 7) uses three independent batches:
``D_in`` for the inner adaptation gradient, ``D_o`` for the outer gradient at
the adapted point, and ``D_h`` for the Hessian estimate.  We never materialise
the Hessian: ``(I − α∇²f)v = v − α·HVP(w, v)`` with the HVP computed by
forward-over-reverse ``jax.jvp`` through ``jax.grad`` — exact and O(params).

``first_order=True`` gives the FO-MAML variant (drops the Hessian term).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax

from repro.utils import tree_axpy

LossFn = Callable[..., Any]   # loss_fn(params, batch, rng) -> (scalar, aux)


def _grad(loss_fn: LossFn, params, batch, rng):
    def scalar_loss(p):
        out = loss_fn(p, batch, rng)
        return out[0] if isinstance(out, tuple) else out
    return jax.grad(scalar_loss)(params)


def adapt(loss_fn: LossFn, params, batch, alpha: float, rng=None):
    """One inner SGD step: w' = w − α ∇f(w; D_in)  (the personalization step)."""
    g = _grad(loss_fn, params, batch, rng)
    return tree_axpy(-alpha, g, params)


def hvp(loss_fn: LossFn, params, batch, vector, rng=None):
    """Hessian-vector product ∇²f(w; D_h) · v via forward-over-reverse."""
    def grad_fn(p):
        return _grad(loss_fn, p, batch, rng)
    return jax.jvp(grad_fn, (params,), (vector,))[1]


def perfed_grad(loss_fn: LossFn, params, batches: Dict[str, Any], alpha: float,
                *, first_order: bool = False, rng=None):
    """Stochastic meta-gradient ∇̃F_i(w) of Eq. (7).

    ``batches`` carries the three independent samples: ``{"inner": D_in,
    "outer": D_o, "hessian": D_h}``.  Returns a pytree like ``params``.
    """
    r1 = r2 = r3 = None
    if rng is not None:
        r1, r2, r3 = jax.random.split(rng, 3)
    w_adapted = adapt(loss_fn, params, batches["inner"], alpha, r1)
    g_outer = _grad(loss_fn, w_adapted, batches["outer"], r2)
    if first_order:
        return g_outer
    h = hvp(loss_fn, params, batches["hessian"], g_outer, r3)
    return tree_axpy(-alpha, h, g_outer)


def perfed_loss(loss_fn: LossFn, params, batches: Dict[str, Any], alpha: float,
                rng=None):
    """F_i(w) = f_i(w − α∇f_i(w; D_in); D_o) — the meta-objective value."""
    r1, r2 = (jax.random.split(rng) if rng is not None else (None, None))
    w_adapted = adapt(loss_fn, params, batches["inner"], alpha, r1)
    out = loss_fn(w_adapted, batches["outer"], r2)
    return out[0] if isinstance(out, tuple) else out


def perfed_grad_exact(loss_fn: LossFn, params, batch, alpha: float, rng=None):
    """Autodiff oracle: d/dw f(w − α∇f(w)) on a single batch.

    Used by tests to validate `perfed_grad` — with identical batches for
    inner/outer/hessian the two must agree to numerical precision.
    """
    def meta_obj(p):
        w_ad = adapt(loss_fn, p, batch, alpha, rng)
        out = loss_fn(w_ad, batch, rng)
        return out[0] if isinstance(out, tuple) else out
    return jax.grad(meta_obj)(params)
