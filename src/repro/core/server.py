"""Algorithm 1 — the PerFedS² parameter-server round logic (simulation path).

This is the *protocol* object: it owns the global model, collects arriving
client payloads, advances the round once ``A`` of them are in (semi-sync),
and decides who receives the new model (the round's participants plus any
client whose staleness exceeded ``S``).  ``mode`` generalises it:

  sync  → A = n   (classic synchronous round)
  semi  → A = A   (the paper)
  async → A = 1   (update on every arrival)

Wall-clock time, channels and scheduling live in ``fl/simulation.py``; model
math (what a "payload" is) lives in ``fl/client.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.stale_aggregate import stale_aggregate_tree


@dataclass
class ServerConfig:
    n_ues: int
    participants_per_round: int      # A
    staleness_bound: int             # S
    beta: float                      # global step size
    mode: str = "semi"               # sync | semi | async
    staleness_discount: float = 1.0  # SAFA/FedSA-style λ^τ payload weighting
                                     # (refs [20][21]); 1.0 = paper's Eq. (8)


class SemiSyncServer:
    """Collects payloads; applies Eq. (8); tracks staleness and distribution."""

    def __init__(self, params: Any, cfg: ServerConfig):
        self.cfg = cfg
        self.params = params
        self.round = 0
        self.a = {"sync": cfg.n_ues, "semi": cfg.participants_per_round,
                  "async": 1}[cfg.mode]
        # effective close threshold for the CURRENT round: equals ``a``
        # until ``set_live_cap`` clamps it to live membership (open-world
        # churn: a cell that shrinks below A must keep closing — smaller —
        # rounds instead of live-locking).  Frozen between cap updates so
        # ``arrivals_until_round`` is stable across one driver drain.
        self._target = self.a
        self._live_cap: Optional[int] = None
        # which UEs currently exist (scenario churn departs/joins them);
        # inactive UEs are never distributed to — a pending upload from a
        # UE that departed before its round closed still aggregates, but
        # must not resurrect it with a fresh model
        self.ue_active = np.ones(cfg.n_ues, dtype=bool)
        # version of the global model each UE last received
        self.ue_version = np.zeros(cfg.n_ues, dtype=np.int64)
        # (ue, payload, staleness-at-arrival) per pending upload
        self._pending: List[Tuple[int, Any, int]] = []
        # segment-pending uploads from the batch-wise feed: (ues, taus,
        # stacked payload tree) per drained batch, concatenated at close
        self._pending_seg: List[Tuple[np.ndarray, np.ndarray, Any]] = []
        self._seg_n = 0                  # lanes across _pending_seg (O(1))
        # bookkeeping for analysis / tests
        self.history_pi: List[np.ndarray] = []       # realised Π rows
        self.history_staleness: List[np.ndarray] = []

    # ------------------------------------------------------------------
    @property
    def target(self) -> int:
        """The current round's effective close threshold (≤ A)."""
        return self._target

    def pending_uploads(self) -> int:
        """Uploads held for the currently open round (both feed paths)."""
        return len(self._pending) + self._seg_n

    def pending_ue_set(self) -> set:
        """UEs with an upload held for the open round (both feed paths) —
        the ``pre_drain`` live-cap computation subtracts these from the
        members that can still produce an arrival."""
        out = {ue for ue, _, _ in self._pending}
        for ues, _, _ in self._pending_seg:
            out.update(int(u) for u in ues)
        return out

    def set_live_cap(self, members: int, in_flight: int) -> None:
        """Clamp the effective round size to what can still arrive.

        ``target = min(A, pending + in_flight)``: every upload already
        held counts, plus each live member whose cycle is still in flight
        can contribute at most one more before the close — the round
        never waits for uploads no existing UE can produce.  The caller
        (``TopologyAdapter.pre_drain``) computes ``in_flight`` as live
        members without a pending upload here.  Caps are pushed only
        BETWEEN drains, so the threshold is constant while a drain is in
        flight — the drain invariant (at most one round closes, on the
        last lane) is preserved.  With membership ≥ A this is exactly
        ``target = A``: closed-world runs are bitwise unaffected.  When
        the clamp lands at (or below) the pending count no future arrival
        will trigger the close — ``flush`` closes such a round.
        """
        self._live_cap = int(members)
        p = self.pending_uploads()
        self._target = max(1, min(self.a, p + max(int(in_flight), 0)))

    def activate(self, ue: int) -> None:
        """(Re-)join: the UE exists again and starts from the current
        round's model (the caller hands it the params; version = round
        means staleness 0)."""
        self.ue_active[ue] = True
        self.ue_version[ue] = self.round

    def deactivate(self, ue: int) -> None:
        """Depart: no future distribution; any in-flight upload is the
        caller's to cancel (driver epoch bump)."""
        self.ue_active[ue] = False

    def arrivals_until_round(self) -> int:
        """How many more uploads close the current round (target − pending).

        Until that many arrive, no global update, distribution, or
        cancellation can happen — which is exactly what lets the simulator
        drain that many events and compute their payloads as one batch.
        """
        return self._target - len(self._pending) - self._seg_n

    def staleness(self, ue: int) -> int:
        """τ_k^i — rounds since UE i last received the global model."""
        return self.round - int(self.ue_version[ue])

    def on_arrival(self, ue: int, payload: Any) -> Optional[Dict[str, Any]]:
        """Register one client upload.  Returns None while the round is open;
        once the A-th payload arrives, applies the global update and returns
        {"round", "distribute": [ue...], "params"}.
        """
        if self._pending_seg:
            raise RuntimeError("segment uploads pending; feed rounds "
                               "through on_arrival_batch consistently")
        self._pending.append((ue, payload, self.staleness(ue)))
        if len(self._pending) < self._target:
            return None
        return self._close_pending()

    def _close_pending(self) -> Dict[str, Any]:
        arrived = self._pending
        self._pending = []
        # --- Eq. (8): w_{k+1} = w_k − β/A Σ_{i∈A_k} ∇̃F_i(w_{k−τ_k^i}),
        # optionally λ^τ staleness-discounted — the discount folds into the
        # aggregation mask, so every mode shares the one fused kernel path --
        mask = self._weights([tau for _, _, tau in arrived])
        self.params = stale_aggregate_tree(
            self.params, [g for _, g, _ in arrived],
            jnp.asarray(mask, jnp.float32), beta=self.cfg.beta)
        return self._advance_round([i for i, _, _tau in arrived])

    def on_arrival_batch(self, ues: np.ndarray, payloads: Any,
                         taus: Optional[np.ndarray] = None
                         ) -> Optional[Dict[str, Any]]:
        """Segment feed: one drained batch of uploads with the payloads
        STACKED (leading lane axis, arrival order) — the batch-wise
        driver path.

        Returns ``None`` while the round stays open.  On the segment
        whose last lane is the A-th pending upload, the pending segments
        are concatenated in arrival order and Eq. (8) runs ONCE over the
        stacked tree — the summation order (stacked row order) is exactly
        the per-arrival path's, so trajectories match.  The driver's
        drain invariant guarantees a segment never overshoots A (the
        drain breaks on the closing arrival); ``taus`` overrides the
        staleness-at-arrival vector (the hierarchy stamps transient
        visiting versions and must snapshot τ before reverting them).
        """
        if self._pending:
            raise RuntimeError("per-arrival uploads pending; feed rounds "
                               "through on_arrival consistently")
        # simlint: disable-next=SIM202 -- host lane-index list
        ues = np.asarray(ues, dtype=np.int64)
        if taus is None:
            taus = self.round - self.ue_version[ues]
        # simlint: disable-next=SIM202 -- taus is host bookkeeping
        self._pending_seg.append((ues, np.asarray(taus, np.int64), payloads))
        self._seg_n += len(ues)
        if self._seg_n > self._target:
            raise RuntimeError(f"segment overshoots target={self._target}: "
                               f"{self._seg_n} lanes pending")
        if self._seg_n < self._target:
            return None
        return self._close_segments()

    def _close_segments(self) -> Dict[str, Any]:
        segs = self._pending_seg
        self._pending_seg, self._seg_n = [], 0
        all_ues = np.concatenate([u for u, _, _ in segs])
        all_taus = np.concatenate([t for _, t, _ in segs])
        mask = self._weights(all_taus)
        if len(segs) == 1:
            stacked = segs[0][2]
        else:
            stacked = jax.tree.map(
                lambda *xs: jnp.concatenate(
                    [jnp.asarray(x) for x in xs], axis=0),
                *[p for _, _, p in segs])
        self.params = stale_aggregate_tree(
            self.params, stacked, jnp.asarray(mask, jnp.float32),
            beta=self.cfg.beta)
        return self._advance_round([int(u) for u in all_ues])

    def flush(self) -> Optional[Dict[str, Any]]:
        """Close the open round NOW if its pending uploads already meet
        the live-cap-clamped target.

        Churn can lower the target to (or below) the pending count
        *after* those uploads arrived — every remaining member's upload
        is already in — so no future arrival exists to trigger the
        ordinary close and waiting would live-lock.  Returns the round
        result, or ``None`` while more arrivals are still possible."""
        p = self.pending_uploads()
        if p == 0 or p < self._target:
            return None
        if self._pending:
            return self._close_pending()
        return self._close_segments()

    def on_round_batch(self, ues: Sequence[int],
                       aggregate_fn: Callable) -> Dict[str, Any]:
        """Fused fast path: a full round of uploads arrives at once.

        The simulator drains exactly the A arrivals that close the round and
        delegates the Eq. (8) math to ``aggregate_fn(params, weights) →
        new_params`` (the engine's single fused dispatch: payload
        computation + masked stale aggregation).  Protocol state — rounds,
        Π, staleness, the distribution rule — stays here, identical to the
        per-arrival path.
        """
        if self._pending or self._pending_seg:
            raise RuntimeError("pending uploads exist; use on_arrival / "
                               "on_arrival_batch")
        if len(ues) != self._target:
            raise ValueError(f"round batch needs exactly target="
                             f"{self._target} uploads, got {len(ues)}")
        weights = self._weights([self.staleness(u) for u in ues])
        self.params = aggregate_fn(self.params, weights)
        return self._advance_round(list(ues))

    # ------------------------------------------------------------------
    def _weights(self, taus: Sequence[int]) -> np.ndarray:
        """Aggregation mask: 1s, or normalised λ^τ staleness discounts."""
        lam = self.cfg.staleness_discount
        if lam < 1.0:
            # simlint: disable-next=SIM202 -- taus is a host int list
            wts = np.array([lam ** tau for tau in taus])
            # normalise by the realised round size (== A except for
            # live-cap-clamped rounds under churn)
            return wts * (len(taus) / max(wts.sum(), 1e-12))
        return np.ones(len(taus))

    def _advance_round(self, arrived_ues: List[int]) -> Dict[str, Any]:
        pi_row = np.zeros(self.cfg.n_ues, dtype=np.int64)
        # simlint: disable-next=SIM202 -- host staleness counters
        stale_row = np.array([self.staleness(i) for i in range(self.cfg.n_ues)])
        for i in arrived_ues:
            pi_row[i] = 1
        self.history_pi.append(pi_row)
        self.history_staleness.append(stale_row)

        self.round += 1
        # --- distribution rule (Alg. 1 line 13-15) -------------------------
        # departed UEs are filtered out: an upload from a UE that left
        # while pending still aggregated above, but distribution would
        # resurrect it with a fresh cycle
        distribute = sorted(
            {i for i in arrived_ues if self.ue_active[i]}
            | {i for i in range(self.cfg.n_ues)
               if self.ue_active[i]
               and self.staleness(i) > self.cfg.staleness_bound})
        for i in distribute:
            self.ue_version[i] = self.round
        if self._live_cap is not None:
            # re-arm the next round's threshold from the last cap push
            # (pending is empty again; refreshed at the next pre_drain)
            self._target = max(1, min(self.a, max(self._live_cap, 1)))
        return {"round": self.round, "distribute": distribute,
                "params": self.params}

    # ------------------------------------------------------------------
    def pi_matrix(self) -> np.ndarray:
        """Realised scheduling matrix Π (rows = completed rounds)."""
        if not self.history_pi:
            return np.zeros((0, self.cfg.n_ues), dtype=np.int64)
        return np.stack(self.history_pi)

    def realised_eta(self) -> np.ndarray:
        """Empirical relative participation frequencies (Eq. 15)."""
        pi = self.pi_matrix()
        tot = pi.sum()
        return pi.sum(0) / max(tot, 1)
