"""Convergence theory — Sec. IV (Theorem 1, Corollary 1, Lemmas 1–3).

These are the analytic expressions the scheduler consumes (A*, K* come from
this bound via Eq. 42/43) and that the tests/benchmarks validate empirically.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class SmoothnessParams:
    """Problem constants of Assumptions 2–5."""
    L: float = 1.0          # gradient Lipschitz constant of f_i
    C: float = 1.0          # gradient bound ‖∇f_i‖ ≤ C
    rho: float = 1.0        # Hessian Lipschitz constant
    sigma_G: float = 1.0    # per-sample gradient variance
    sigma_H: float = 1.0    # per-sample Hessian variance
    gamma_G: float = 1.0    # inter-client gradient diversity
    gamma_H: float = 1.0    # inter-client Hessian diversity


def smoothness_F(p: SmoothnessParams, alpha: float) -> float:
    """Lemma 1: L_F = 4L + α·ρ·C."""
    return 4.0 * p.L + alpha * p.rho * p.C


def sigma_F2(p: SmoothnessParams, alpha: float, d_in: int, d_o: int,
             d_h: int) -> float:
    """Lemma 2 (Eq. 24): variance of the stochastic meta-gradient."""
    t1 = p.C ** 2 + p.sigma_G ** 2 * (1.0 / d_o + (alpha * p.L) ** 2 / d_in)
    t2 = 1.0 + p.sigma_H ** 2 * alpha ** 2 / (4.0 * d_h)
    return 12.0 * t1 * t2 - 12.0 * p.C ** 2


def gamma_F2(p: SmoothnessParams, alpha: float) -> float:
    """Lemma 3 (Eq. 26): γ_F² = 3 C² α² γ_H² + 192 γ_G²."""
    return 3.0 * p.C ** 2 * alpha ** 2 * p.gamma_H ** 2 + 192.0 * p.gamma_G ** 2


def step_condition(l_f: float, beta: float, s: int) -> float:
    """Theorem 1 prerequisite (Eq. 27): L_F β² − β + 2 L_F² β² S² ≤ 1.

    Returns the LHS; callers check ``step_condition(...) <= 1``.
    """
    return l_f * beta ** 2 - beta + 2.0 * l_f ** 2 * beta ** 2 * s ** 2


def max_feasible_beta(l_f: float, s: int) -> float:
    """Largest β satisfying Eq. (27) (quadratic in β, positive root)."""
    a = l_f + 2.0 * l_f ** 2 * s ** 2
    # a β² − β − 1 ≤ 0  →  β ≤ (1 + sqrt(1 + 4a)) / (2a)
    return (1.0 + math.sqrt(1.0 + 4.0 * a)) / (2.0 * a)


def fosp_bound(*, loss_gap: float, beta: float, k: int, a: int, s: int,
               l_f: float, sig_f2: float, gam_f2: float) -> float:
    """Theorem 1 (Eq. 28): upper bound on (1/K) Σ E‖∇F(w_k)‖².

        2(F(w₀)−F(w*)) / (βK) + 4(L_F β + 2 L_F² β² S²)(σ_F²+γ_F²)·√A
    """
    t1 = 2.0 * loss_gap / (beta * k)
    t2 = 4.0 * (l_f * beta + 2.0 * l_f ** 2 * beta ** 2 * s ** 2) \
        * (sig_f2 + gam_f2) * math.sqrt(a)
    return t1 + t2


def corollary1_rates(epsilon: float) -> dict:
    """Corollary 1 parameter scalings for an ε-FOSP."""
    return {
        "K": epsilon ** -3,
        "beta": epsilon ** 2,
        "S": epsilon ** -1,
        "A": epsilon ** -2,
    }
