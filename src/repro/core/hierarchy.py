"""Hierarchical cell → cloud aggregation (HPFL-style, cf. arXiv:2303.10580).

Each cell runs its own ``SemiSyncServer`` — the Algorithm-1 / Eq.-8
semi-synchronous protocol, unchanged, over the UEs currently associated
with that cell — and a cloud tier periodically merges the per-cell edge
models with ``masked_aggregate_tree`` (the same unified aggregation API the
edge update itself uses), weighted by each cell's arrival count since the
last merge.  After a merge every edge server continues from the merged
model; UEs receive it lazily, at their next distribution event, exactly as
they receive ordinary round updates.

Cell membership is dynamic: ``handover(ue, src, dst)`` retires the UE from
``src`` (a sentinel version means "never considered stale here") and grafts
its *current staleness* onto ``dst``'s round clock — so a UE that hands
over mid-computation shows up in the new cell exactly as stale as it really
is, and the τ > S forced-refresh rule fires across cell boundaries
(handover-induced staleness).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.server import SemiSyncServer, ServerConfig
from repro.kernels.stale_aggregate import masked_aggregate_tree
from repro.obs import trace as obs

# version sentinel: staleness = round − version stays hugely negative, so a
# non-member UE never triggers this cell's forced-refresh rule
NON_MEMBER = np.int64(1) << 60


@dataclass(frozen=True)
class HierarchyConfig:
    n_cells: int
    cloud_sync_every: int = 5        # merge every N completed edge rounds
    cell_weighting: str = "arrivals"  # arrivals | uniform


class HierarchicalServer:
    """Per-cell ``SemiSyncServer`` edge tier + periodic cloud merge."""

    def __init__(self, params: Any, cell_cfgs: Sequence[ServerConfig],
                 hcfg: HierarchyConfig,
                 members: Sequence[np.ndarray]):
        if len(cell_cfgs) != hcfg.n_cells or len(members) != hcfg.n_cells:
            raise ValueError("need one ServerConfig + member set per cell")
        self.hcfg = hcfg
        self.cells = [SemiSyncServer(params, cfg) for cfg in cell_cfgs]
        n = cell_cfgs[0].n_ues
        # −1 = not a member of any cell (dormant / departed under the
        # open-world scenario; a closed-world init covers every index)
        self.member_cell = np.full(n, -1, dtype=np.int64)
        for c, srv in enumerate(self.cells):
            srv.ue_version[:] = NON_MEMBER
            # simlint: disable-next=SIM202 -- host membership list
            idx = np.asarray(members[c], dtype=np.int64)
            srv.ue_version[idx] = 0
            self.member_cell[idx] = c
        self.cloud_params = params
        self.edge_rounds = 0             # completed rounds across all cells
        self.cloud_rounds = 0            # completed cloud merges
        self.departed_arrivals = 0       # uploads landing after a handover
        self._arrivals_since_sync = np.zeros(hcfg.n_cells, dtype=np.int64)
        self.history_pi: List[np.ndarray] = []   # edge-round order, all cells
        self.history_cell: List[int] = []

    # ------------------------------------------------------------------
    def cell(self, c: int) -> SemiSyncServer:
        return self.cells[c]

    def arrivals_until_round(self, c: int) -> int:
        return self.cells[c].arrivals_until_round()

    def set_live_cap(self, c: int, members: int, in_flight: int) -> None:
        """Clamp cell ``c``'s effective round size to live membership
        (see ``SemiSyncServer.set_live_cap``)."""
        self.cells[c].set_live_cap(members, in_flight)

    def flush(self, c: int) -> Optional[Dict[str, Any]]:
        """Close cell ``c``'s round if its clamped target is already met
        (``SemiSyncServer.flush``), with the full hierarchy bookkeeping —
        membership-filtered distribution, cloud-merge cadence."""
        res = self.cells[c].flush()
        return None if res is None else self._finish(c, res)

    def pending_uploads(self) -> int:
        return sum(srv.pending_uploads() for srv in self.cells)

    def open_rounds(self) -> int:
        """Cells currently holding uploads toward an unclosed round."""
        return sum(1 for srv in self.cells if srv.pending_uploads() > 0)

    # --- open-world UE lifecycle (scenario churn) ----------------------
    def join(self, ue: int, c: int) -> None:
        """Activate ``ue`` as a member of cell ``c`` with a fresh model
        (version = the cell's current round → staleness 0)."""
        self.member_cell[ue] = c
        self.cells[c].ue_version[ue] = self.cells[c].round

    def leave(self, ue: int) -> None:
        """Depart ``ue``: it stops being a member anywhere.  Its pending
        upload (if any) still aggregates when the round closes, but
        ``_finish``'s membership filter keeps it out of the distribution
        — no resurrection.  The caller cancels in-flight computation via
        the driver's epoch mechanism."""
        c = int(self.member_cell[ue])
        if c >= 0:
            self.cells[c].ue_version[ue] = NON_MEMBER
        self.member_cell[ue] = -1

    @property
    def params(self) -> Any:
        """Latest cloud model (cell 0's edge model before the first merge)."""
        return self.cloud_params if self.cloud_rounds else \
            self.cells[0].params

    # ------------------------------------------------------------------
    def handover(self, ue: int, src: int, dst: int) -> None:
        """Move a UE between cells, carrying its staleness across."""
        if src == dst:
            return
        tau = self.cells[src].staleness(ue)
        self.cells[src].ue_version[ue] = NON_MEMBER
        # round − version = τ in the new cell's clock (version may go
        # negative for a UE staler than the cell is old — still correct)
        self.cells[dst].ue_version[ue] = self.cells[dst].round - max(tau, 0)
        self.member_cell[ue] = dst

    def _visiting_version(self, c: int, ue: int) -> np.int64:
        """A version giving a *departed* UE a sensible τ in cell ``c``'s
        clock: its current staleness, read from the cell it now lives in."""
        cur = int(self.member_cell[ue])
        if cur < 0:
            # departed the whole network (open-world churn): no live round
            # clock to read — weight the straggler upload as fresh
            return np.int64(self.cells[c].round)
        tau = max(int(self.cells[cur].staleness(ue)), 0)
        return np.int64(self.cells[c].round - tau)

    # ------------------------------------------------------------------
    def on_arrival(self, c: int, ue: int,
                   payload: Any) -> Optional[Dict[str, Any]]:
        srv = self.cells[c]
        # an upload can complete at a cell the UE has since handed over
        # from (it was in flight when the handover hit) — give it a sane
        # staleness for the weighting, without resurrecting membership
        departed = int(self.member_cell[ue]) != c
        if departed:
            self.departed_arrivals += 1
            srv.ue_version[ue] = self._visiting_version(c, ue)
        res = srv.on_arrival(ue, payload)
        if res is None:
            if departed:
                srv.ue_version[ue] = NON_MEMBER
            return None
        return self._finish(c, res)

    def on_arrival_batch(self, cells: np.ndarray, ues: np.ndarray,
                         payloads: Any) -> Optional[Dict[str, Any]]:
        """Multi-cell segment feed of one drained batch (payloads stacked
        in lane order — the driver's batch-wise path).

        The drain invariant makes this simple: at most ONE round closes
        per drain and its closing arrival is the batch's LAST lane.  So
        lanes are fed per cell with the last lane's cell processed LAST —
        every other cell's visiting-staleness reads of round clocks happen
        before the close can advance one.  Departed lanes get a transient
        visiting version for the τ weighting, reverted to NON_MEMBER
        unless they are the literal closing arrival — whose stamp the
        per-arrival path lets ``_advance_round``'s staleness snapshot see
        (``_finish`` strips it from membership afterwards either way).
        """
        # simlint: disable-next=SIM202 -- host routing lists, not arrays
        cells = np.asarray(cells, dtype=np.int64)
        # simlint: disable-next=SIM202 -- host routing lists, not arrays
        ues = np.asarray(ues, dtype=np.int64)
        last_cell = int(cells[-1])
        order = [c for c in dict.fromkeys(int(x) for x in cells)
                 if c != last_cell] + [last_cell]
        lanes_of = [np.nonzero(cells == c)[0] for c in order]

        def seg_of(ln: np.ndarray) -> Any:
            """Per-cell rows of the stacked payloads, in lane (arrival)
            order — a contiguous slice when the driver cell-sorted the
            batch (its fast path), one gather per cell otherwise.
            Payload trees are [k, model]-sized, so avoiding whole-tree
            copies here is what keeps the feed device-bound."""
            if len(ln) == len(ues):
                return payloads
            if int(ln[-1]) - int(ln[0]) + 1 == len(ln):    # contiguous
                lo, hi = int(ln[0]), int(ln[-1]) + 1
                return jax.tree.map(lambda x: x[lo:hi], payloads)
            lj = jnp.asarray(ln)
            return jax.tree.map(
                lambda x: jnp.take(jnp.asarray(x), lj, axis=0), payloads)

        result: Optional[Dict[str, Any]] = None
        for c, lanes in zip(order, lanes_of):
            seg = seg_of(lanes)
            srv = self.cells[c]
            cus = ues[lanes]
            departed = [int(u) for u in cus
                        if int(self.member_cell[u]) != c]
            for u in departed:
                self.departed_arrivals += 1
                srv.ue_version[u] = self._visiting_version(c, u)
            taus = srv.round - srv.ue_version[cus]      # τ at arrival
            final = int(ues[-1]) if c == last_cell else None
            for u in departed:
                if u != final:
                    srv.ue_version[u] = NON_MEMBER
            res = srv.on_arrival_batch(cus, seg, taus=taus)
            if res is None:
                # possible only when the drain ended on heap exhaustion —
                # then the last lane closed nothing, so revert its stamp
                if final is not None and final in departed:
                    srv.ue_version[final] = NON_MEMBER
                continue
            assert c == last_cell, "drain invariant: only the last lane's " \
                                   "cell may close a round"
            result = self._finish(c, res)
        return result

    def on_round_batch(self, c: int, ues: Sequence[int],
                       aggregate_fn: Callable) -> Dict[str, Any]:
        srv = self.cells[c]
        for u in ues:
            if int(self.member_cell[u]) != c:
                self.departed_arrivals += 1
                srv.ue_version[u] = self._visiting_version(c, u)
        return self._finish(c, srv.on_round_batch(ues, aggregate_fn))

    def _finish(self, c: int, res: Dict[str, Any]) -> Dict[str, Any]:
        self.edge_rounds += 1
        self.history_pi.append(self.cells[c].history_pi[-1])
        self.history_cell.append(c)
        # realised round size (== A except live-cap-clamped churn rounds)
        self._arrivals_since_sync[c] += int(self.cells[c].history_pi[-1].sum())
        res = dict(res)
        # the cell's _advance_round stamped fresh versions on everyone it
        # distributes to — departed UEs must not be resurrected as members
        # here, nor receive this cell's model (they live elsewhere now)
        srv = self.cells[c]
        keep = []
        for i in res["distribute"]:
            if int(self.member_cell[i]) == c:
                keep.append(i)
            else:
                srv.ue_version[i] = NON_MEMBER
        res["distribute"] = keep
        res["cell"] = c
        res["round"] = self.edge_rounds      # global edge-round clock
        res["cloud_synced"] = False
        every = self.hcfg.cloud_sync_every
        if every > 0 and self.edge_rounds % every == 0:
            self.cloud_sync()
            res["params"] = self.cells[c].params   # the merged model
            res["cloud_synced"] = True
        return res

    # ------------------------------------------------------------------
    def cloud_sync(self) -> None:
        """Merge cell models: weighted mean via ``masked_aggregate_tree``."""
        with obs.CURRENT.span("cloud_sync"):
            obs.CURRENT.add("hierarchy.cloud_syncs")
            self._cloud_sync()

    def _cloud_sync(self) -> None:
        if self.hcfg.cell_weighting == "arrivals" and \
                self._arrivals_since_sync.sum() > 0:
            w = self._arrivals_since_sync.astype(np.float32)
        else:
            w = np.ones(self.hcfg.n_cells, np.float32)
        merged = obs.CURRENT.device_call(
            "cloud_sync", masked_aggregate_tree,
            [srv.params for srv in self.cells], jnp.asarray(w))
        ref = self.cells[0].params
        merged = jax.tree.map(
            lambda m, p: m.astype(jnp.asarray(p).dtype), merged, ref)
        for srv in self.cells:
            srv.params = merged
        self.cloud_params = merged
        self.cloud_rounds += 1
        self._arrivals_since_sync[:] = 0

    # ------------------------------------------------------------------
    def pi_matrix(self) -> np.ndarray:
        """Realised Π across all cells, rows in edge-round completion order."""
        if not self.history_pi:
            n = self.cells[0].cfg.n_ues
            return np.zeros((0, n), dtype=np.int64)
        return np.stack(self.history_pi)

    def realised_eta(self) -> np.ndarray:
        pi = self.pi_matrix()
        tot = pi.sum()
        return pi.sum(0) / max(tot, 1)
