"""Semi-synchronous aggregation as a first-class SPMD training feature.

This is the datacenter-scale mapping of Alg. 1: each *cohort* (= one pod of
the multi-pod mesh, or a slice of the data axis) plays the role of a UE.  The
server's "wait for A of n" becomes a **masked psum across the cohort axis**;
gradients "in flight" live in a per-cohort buffer carried in the train state
(sharded over the cohort axis so each pod keeps exactly one extra gradient).

Per step (round k), given the Alg.-2 schedule mask π_k:

  1. w_{k+1} = w_k − β/A · Σ_{i: π_i=1} buf_i          (Eq. 8 — arriving grads,
     possibly computed against w_{k−τ_i}: that's exactly what the buffer holds)
  2. refresh: cohorts with π_i=1 (or staleness > S) compute a fresh PerFed
     meta-gradient (Eq. 7) against w_{k+1} and overwrite their buffer slot
  3. staleness counters advance; the simulator (fl/simulation.py) decides the
     masks and wall-clock times — this module is pure SPMD math.

With n_cohorts=1 and π=[1] this degenerates exactly to synchronous
Per-FedAvg (the paper's PerFed-SYN baseline) — used for the single-pod
roofline profile.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ExperimentConfig
from repro.core import perfed
from repro.kernels.stale_aggregate import (masked_aggregate_tree,
                                           stale_aggregate_tree)
from repro.optim import Optimizer, clip_by_global_norm


class SemiSyncState(NamedTuple):
    params: Any                  # meta model w_k
    opt_state: Any               # server optimizer state (empty for β-SGD)
    buffers: Any                 # per-cohort pending grads [n_cohorts, ...]
    staleness: jax.Array         # [n_cohorts] int32 — rounds since last refresh
    step: jax.Array              # round counter k


def init_state(model, rng, optimizer: Optimizer, n_cohorts: int
               ) -> SemiSyncState:
    params = model.init(rng)
    buffers = jax.tree.map(
        lambda p: jnp.zeros((n_cohorts,) + p.shape, p.dtype), params)
    return SemiSyncState(
        params=params,
        opt_state=optimizer.init(params),
        buffers=buffers,
        staleness=jnp.zeros((n_cohorts,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def _cohort_grads(model, cfg: ExperimentConfig, params, cohort_batches,
                  rng) -> Any:
    """PerFed meta-gradient per cohort: vmap over the leading cohort dim.

    ``cohort_batches`` = {"inner": ..., "outer": ..., "hessian": ...} with
    each leaf shaped [n_cohorts, B_c, ...].
    """
    fl = cfg.fl

    def one(batches, r):
        if fl.algorithm == "perfed":
            return perfed.perfed_grad(model.loss, params, batches, fl.alpha,
                                      first_order=fl.first_order, rng=r)
        # fedavg-style plain gradient on the union batch
        def scalar(p):
            out = model.loss(p, batches["outer"], r)
            return out[0] if isinstance(out, tuple) else out
        return jax.grad(scalar)(params)

    n = jax.tree.leaves(cohort_batches)[0].shape[0]
    rngs = jax.random.split(rng, n)
    return jax.vmap(one, in_axes=(0, 0))(cohort_batches, rngs)


def uses_fused_eq8(optimizer: Optimizer, cfg: ExperimentConfig) -> bool:
    """Pure Eq. (8) — β-SGD, no clipping — is exactly the fused masked
    stale-aggregation op; anything fancier needs the masked mean first."""
    return optimizer.name == "sgd" and not cfg.train.grad_clip


def make_semi_sync_step(model, cfg: ExperimentConfig, optimizer: Optimizer,
                        n_cohorts: int) -> Callable:
    """Build the jittable semi-synchronous round function.

    step(state, cohort_batches, mask, rng) -> (state, metrics)
      mask: float [n_cohorts] — π_k (1 = this cohort's gradient arrives now)
    """
    fl = cfg.fl

    fused_eq8 = uses_fused_eq8(optimizer, cfg)

    def step_fn(state: SemiSyncState, cohort_batches, mask: jax.Array, rng
                ) -> Tuple[SemiSyncState, Dict[str, jax.Array]]:
        # -- 1) server update from arriving (possibly stale) gradients -------
        # via the unified aggregation API (same code path as the simulation
        # server and the engine's fused round / Pallas kernel)
        if fused_eq8:
            gnorm = jnp.zeros(())
            new_params = stale_aggregate_tree(state.params, state.buffers,
                                              mask, beta=fl.beta)
            new_opt = state.opt_state
        else:
            agg = masked_aggregate_tree(state.buffers, mask)
            if cfg.train.grad_clip:
                agg, gnorm = clip_by_global_norm(agg, cfg.train.grad_clip)
            else:
                gnorm = jnp.zeros(())
            new_params, new_opt = optimizer.update(agg, state.opt_state,
                                                   state.params, fl.beta)

        # -- 2) refresh buffers: scheduled cohorts (+ over-stale ones) -------
        refresh = (mask > 0) | (state.staleness > fl.staleness_bound)
        fresh = _cohort_grads(model, cfg, new_params, cohort_batches, rng)
        new_buffers = jax.tree.map(
            lambda buf, fg: jnp.where(
                refresh.reshape((-1,) + (1,) * (buf.ndim - 1)),
                fg.astype(buf.dtype), buf),
            state.buffers, fresh)

        # -- 3) staleness bookkeeping ----------------------------------------
        new_staleness = jnp.where(refresh, 0, state.staleness + 1)

        metrics = {
            "grad_norm": gnorm,
            "participants": mask.sum(),
            "max_staleness": new_staleness.max(),
        }
        return SemiSyncState(new_params, new_opt, new_buffers,
                             new_staleness.astype(jnp.int32),
                             state.step + 1), metrics

    return step_fn


# ---------------------------------------------------------------------------
# Plain train step (non-FL baseline / dry-run compute profile)
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(model, rng, optimizer: Optimizer) -> TrainState:
    params = model.init(rng)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def make_train_step(model, cfg: ExperimentConfig, optimizer: Optimizer,
                    *, perfed_step: bool = True) -> Callable:
    """Single-cohort training step.

    ``perfed_step=True`` → the paper-faithful Per-FedAvg step (inner adapt +
    outer grad + HVP correction, Eq. 7) — this is what the roofline profiles.
    ``False`` → plain LM gradient step (the FedAvg / standard baseline).
    """
    fl = cfg.fl

    def step_fn(state: TrainState, batches, rng
                ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if perfed_step:
            grads = perfed.perfed_grad(model.loss, state.params, batches,
                                       fl.alpha, first_order=fl.first_order,
                                       rng=rng)
            loss = perfed.perfed_loss(model.loss, state.params, batches,
                                      fl.alpha, rng=rng)
        else:
            def scalar(p):
                out = model.loss(p, batches["outer"], rng)
                return out[0] if isinstance(out, tuple) else out
            loss, grads = jax.value_and_grad(scalar)(state.params)
        if cfg.train.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, cfg.train.grad_clip)
        else:
            gnorm = jnp.zeros(())
        lr = fl.beta if perfed_step else cfg.train.learning_rate
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, lr)
        return TrainState(new_params, new_opt, state.step + 1), {
            "loss": loss, "grad_norm": gnorm}

    return step_fn
