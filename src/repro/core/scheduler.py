"""UE scheduling — Sec. V-C of the paper.

* ``relative_frequencies`` — η_i (Eq. 15) from equal or distance-derived rates.
* ``estimate_A_K``        — Eq. (42)/(43): A*, K* from the convergence bound.
* ``greedy_schedule``     — Algorithm 2: greedy construction of the periodic
                            participation matrix Π with Σ_i π_k^i = A (Eq. 14).
* ``SchedulingPolicy``    — small protocol bundling "how η is derived" with
                            "how Π is planned", so equal/rates/distance
                            policies compose with sync/semi/async server
                            modes instead of living as if-chains in the
                            simulator and benchmarks (``get_policy``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.config import FLConfig


def relative_frequencies(n: int, mode: str = "equal", *,
                         distances: Optional[np.ndarray] = None,
                         rates: Optional[np.ndarray] = None,
                         kappa: float = 3.8) -> np.ndarray:
    """η vector (sums to 1).

    ``equal``    — η_i = 1/n.
    ``distance`` — η_i ∝ achievable rate ∝ log(1 + d^-κ·const): farther UEs
                   upload slower and naturally participate less (Sec. VI-A-4).
    ``rates``    — proportional to externally supplied average rates.
    """
    if mode == "equal":
        eta = np.ones(n)
    elif mode == "distance":
        assert distances is not None
        snr = np.power(np.maximum(distances, 1.0), -kappa) * 1e9
        eta = np.log1p(snr)
    elif mode == "rates":
        assert rates is not None
        eta = np.asarray(rates, dtype=float)
    else:
        raise ValueError(f"unknown eta mode {mode!r}")
    eta = np.maximum(eta, 1e-9)
    return eta / eta.sum()


def estimate_A_K(fl: FLConfig, *, eta: np.ndarray, epsilon: float,
                 L_F: float, sigma_F2: float, gamma_F2: float,
                 loss_gap: float = 1.0) -> Tuple[int, int]:
    """Optimal participants A* (Eq. 43) and rounds K* (Eq. 42).

    K* ≈ min_i { 2(F(w0)−F(w*)) / (β ε),  S/η_i }
    A* ≈ min_i { ε² / (16 (L_F β + 2 L_F² β² S²)² (σ_F²+γ_F²)²),  1/(η_i S) }
    """
    beta, s = fl.beta, fl.staleness_bound
    k_theory = 2.0 * loss_gap / (beta * epsilon)
    k_eta = (s / eta).max()                       # K ≥ S/η_i for all i (C1.5)
    k_star = max(1, int(np.ceil(min(k_theory, k_eta))))

    denom = 16.0 * (L_F * beta + 2.0 * L_F ** 2 * beta ** 2 * s ** 2) ** 2 \
        * (sigma_F2 + gamma_F2) ** 2
    a_theory = epsilon ** 2 / max(denom, 1e-30)
    a_eta = (1.0 / (eta * s)).min()               # A ≥ 1/(η_i S) (C4.2)
    a_star = max(1, int(np.ceil(min(a_theory, a_eta))))
    return min(a_star, len(eta)), k_star


def greedy_schedule(eta: np.ndarray, A: int, K: int) -> np.ndarray:
    """Algorithm 2 — greedy Π construction.

    Each round, pick the A UEs whose *current* relative participation
    frequency η̂_i lags its target η_i the most (the paper's "poorest first"
    greedy); ties go to lower index, matching the paper's "schedule the first
    A − |picked| UEs" fallback.  Returns Π as an int matrix [K, n].
    """
    n = len(eta)
    assert 1 <= A <= n
    pi = np.zeros((K, n), dtype=np.int64)
    counts = np.zeros(n, dtype=np.int64)
    total = 0
    for k in range(K):
        if total == 0:
            eta_hat = np.zeros(n)
        else:
            eta_hat = counts / total
        deficit = eta - eta_hat
        # candidates whose η̂ has not yet reached target, poorest first
        order = np.argsort(-deficit, kind="stable")
        chosen = [i for i in order if eta_hat[i] <= eta[i]][:A]
        if len(chosen) < A:
            # Alg. 2 line 11-13: fill with the first unchosen UEs
            rest = [i for i in range(n) if i not in chosen]
            chosen += rest[:A - len(chosen)]
        pi[k, chosen] = 1
        counts[chosen] += 1
        total += A
    return pi


def schedule_staleness(pi: np.ndarray) -> np.ndarray:
    """Per-(round, UE) staleness implied by Π: rounds since last participation
    start.  τ_k^i = k − (last round ≤ k where UE i was scheduled)."""
    k_rounds, n = pi.shape
    tau = np.zeros_like(pi)
    last = -np.ones(n, dtype=np.int64)
    for k in range(k_rounds):
        for i in range(n):
            tau[k, i] = k - last[i] - 1 if last[i] >= 0 else k
        last[pi[k] == 1] = k
    return tau


# ---------------------------------------------------------------------------
# Scheduling policies — composable with sync / semi / async server modes
# ---------------------------------------------------------------------------

@runtime_checkable
class SchedulingPolicy(Protocol):
    """How participation targets are derived and planned.

    ``frequencies``  — the η vector (Eq. 15) for a concrete network drop.
    ``plan``         — a Π matrix hitting those targets (Alg. 2 by default).
    ``uniform_drop`` — whether the UE drop should be distance-uniform (the
                       paper's equal-η ablation removes geometry entirely).
    """

    uniform_drop: bool

    def frequencies(self, n: int, net=None) -> np.ndarray: ...

    def plan(self, eta: np.ndarray, A: int, K: int) -> np.ndarray: ...


@dataclass(frozen=True)
class _GreedyPlanMixin:
    """Default Π planner: the paper's Algorithm 2 greedy construction."""

    def plan(self, eta: np.ndarray, A: int, K: int) -> np.ndarray:
        return greedy_schedule(eta, A, K)


@dataclass(frozen=True)
class EqualPolicy(_GreedyPlanMixin):
    """η_i = 1/n; pairs with a distance-uniform drop (Sec. VI-A equal-η)."""

    uniform_drop: bool = True

    def frequencies(self, n: int, net=None) -> np.ndarray:
        return relative_frequencies(n, "equal")


@dataclass(frozen=True)
class RatesPolicy(_GreedyPlanMixin):
    """η_i ∝ mean achievable uplink rate of the drop (Sec. VI-A-4: farther,
    slower UEs naturally participate less)."""

    uniform_drop: bool = False

    def frequencies(self, n: int, net=None) -> np.ndarray:
        if net is None:
            return relative_frequencies(n, "equal")
        return relative_frequencies(n, "rates", rates=net.mean_rates())


@dataclass(frozen=True)
class DistancePolicy(_GreedyPlanMixin):
    """η_i from the closed-form distance proxy (no channel model needed)."""

    uniform_drop: bool = False
    kappa: float = 3.8

    def frequencies(self, n: int, net=None) -> np.ndarray:
        if net is None:
            return relative_frequencies(n, "equal")
        return relative_frequencies(n, "distance", distances=net.distances,
                                    kappa=self.kappa)


# ``distance`` maps to RatesPolicy on purpose: the simulator's historical
# eta_mode="distance" derives η from the mean rates of a distance-dependent
# drop (that IS the paper's Sec. VI-A-4 recipe); the pure closed-form proxy
# stays available as "distance-proxy".
_POLICIES = {
    "equal": EqualPolicy,
    "rates": RatesPolicy,
    "distance": RatesPolicy,
    "distance-proxy": DistancePolicy,
}


def get_policy(name: str) -> SchedulingPolicy:
    """Resolve an ``fl.eta_mode`` string to a SchedulingPolicy instance."""
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {name!r}; "
                         f"known: {sorted(_POLICIES)}") from None


def schedule_period(pi: np.ndarray) -> int:
    """Detect the recurrence period K_p of a schedule (Theorem 3)."""
    k_rounds = pi.shape[0]
    for p in range(1, k_rounds // 2 + 1):
        if k_rounds % p == 0 and np.array_equal(pi[:-p], pi[p:]):
            return p
    return k_rounds
