"""repro.analysis — simlint, the repo's invariant checker.

Static AST checks for the contracts the simulator relies on: RNG draw
schedules (SIM1xx), host/device boundaries in hot-path modules (SIM2xx),
jit purity (SIM3xx), and the observability read-only contract (SIM4xx).

Run it as ``python scripts/simlint.py src`` or
``python -m repro.analysis src``.
"""
from repro.analysis.core import (
    Baseline,
    BaselineEntry,
    Finding,
    LintReport,
    lint_text,
    run_paths,
)

__all__ = [
    "Baseline",
    "BaselineEntry",
    "Finding",
    "LintReport",
    "lint_text",
    "run_paths",
]
