"""``python -m repro.analysis`` — simlint CLI entry point."""
import sys

from repro.analysis.cli import main

sys.exit(main())
