"""simlint core — findings, suppressions, baseline, and the file runner.

The checker is a repo-specific static-analysis pass over the Python AST.
It exists because every correctness incident in PRs 1-6 violated an
*unwritten* invariant: RNG draw schedules that depended on call batching
(the PR-5 mobility bug), implicit device→host syncs in the event-loop hot
path, side effects inside jit-traced functions, and the observability
layer's read-only contract.  ``repro.analysis.rules`` encodes those
contracts as machine-checked rules; this module is the plumbing:

* ``Finding``      — one diagnostic (code, file, line, col, message).
* suppressions     — ``# simlint: disable=SIM202 -- why`` on the finding
  line, ``# simlint: disable-next=...`` on the line above, or
  ``# simlint: disable-file=...`` anywhere for a whole module.
* ``Baseline``     — committed JSON of grandfathered findings; every
  entry carries a one-line justification and matches findings by
  (file, code, stripped source line), so entries survive pure line-number
  drift but die with the code they describe.
* ``run_paths``    — walk files, parse once, apply every registered rule,
  then classify each finding as active / suppressed / baselined.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding", "ModuleInfo", "Baseline", "BaselineEntry", "LintReport",
    "lint_text", "run_paths", "find_repo_root", "DEFAULT_BASELINE_NAME",
]

DEFAULT_BASELINE_NAME = "simlint-baseline.json"

_SUPPRESS_RE = re.compile(
    r"#\s*simlint:\s*(disable|disable-next|disable-file)"
    r"\s*=\s*([A-Za-z0-9_,\s]+?)\s*(?:--\s*(.*\S))?\s*$")


@dataclass(frozen=True)
class Finding:
    """One diagnostic, anchored to a repo-relative file and 1-based line."""
    code: str
    path: str                  # repo-relative posix path
    line: int
    col: int
    message: str
    status: str = "active"     # active | suppressed | baselined

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{self.message}"

    def with_status(self, status: str) -> "Finding":
        return Finding(self.code, self.path, self.line, self.col,
                       self.message, status)


@dataclass
class ModuleInfo:
    """Parsed module handed to every rule: path + AST + source lines."""
    path: str                  # repo-relative posix path
    tree: ast.Module
    lines: List[str]           # raw source lines (1-based via line(n))

    def line(self, n: int) -> str:
        if 1 <= n <= len(self.lines):
            return self.lines[n - 1]
        return ""

    # --- path predicates rules share -----------------------------------
    def in_src_repro(self) -> bool:
        return self.path.startswith("src/repro/")

    def in_obs(self) -> bool:
        return self.path.startswith("src/repro/obs/")

    def is_testish(self) -> bool:
        """Test / example / script code — looser RNG-literal rules."""
        first = self.path.split("/", 1)[0]
        return (first in ("tests", "examples", "scripts", "benchmarks")
                or Path(self.path).name.startswith("test_"))


# Hot-path modules for the SIM2xx host-sync rules (the modules the
# PR-5/PR-6 host-fraction hunts kept returning to).
HOT_PATH_FILES = (
    "src/repro/fl/driver.py",
    "src/repro/fl/engine.py",
    "src/repro/core/server.py",
    "src/repro/core/hierarchy.py",
)
HOT_PATH_PREFIXES = ("src/repro/mobility/",)


def in_hot_path(path: str) -> bool:
    return path in HOT_PATH_FILES or any(
        path.startswith(p) for p in HOT_PATH_PREFIXES)


# ----------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------
class Suppressions:
    """Inline ``# simlint: disable`` pragmas parsed from one module."""

    def __init__(self, lines: Sequence[str]):
        self.at_line: Dict[int, set] = {}
        self.file_wide: set = set()
        for i, text in enumerate(lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            kind = m.group(1)
            codes = {c.strip().upper() for c in m.group(2).split(",")
                     if c.strip()}
            if kind == "disable-file":
                self.file_wide |= codes
            elif kind == "disable-next":
                self.at_line.setdefault(i + 1, set()).update(codes)
            else:
                self.at_line.setdefault(i, set()).update(codes)

    def covers(self, finding: Finding) -> bool:
        codes = self.at_line.get(finding.line, set()) | self.file_wide
        return finding.code in codes or "ALL" in codes


# ----------------------------------------------------------------------
# baseline
# ----------------------------------------------------------------------
@dataclass
class BaselineEntry:
    file: str
    code: str
    match: str                 # stripped source text of the finding line
    why: str                   # one-line justification (required)
    count: int = 1
    used: int = field(default=0, compare=False)

    def to_json(self) -> Dict:
        d = {"file": self.file, "code": self.code, "match": self.match,
             "why": self.why}
        if self.count != 1:
            d["count"] = self.count
        return d


class Baseline:
    """Committed grandfather list.  A finding is *baselined* when an entry
    with the same (file, code) whose ``match`` equals the stripped source
    line still has unused count.  Unmatched entries are *stale* — they
    describe code that no longer exists and should be pruned."""

    def __init__(self, entries: Iterable[BaselineEntry] = ()):
        self.entries = list(entries)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        data = json.loads(path.read_text())
        entries = []
        for i, e in enumerate(data.get("entries", [])):
            why = str(e.get("why", "")).strip()
            if not why:
                raise ValueError(
                    f"{path}: baseline entry #{i} ({e.get('file')}, "
                    f"{e.get('code')}) has no 'why' justification")
            entries.append(BaselineEntry(
                file=e["file"], code=e["code"], match=e["match"],
                why=why, count=int(e.get("count", 1))))
        return cls(entries)

    def save(self, path: Path) -> None:
        payload = {
            "comment": "simlint grandfathered findings; every entry "
                       "needs a one-line 'why'. Regenerate with "
                       "--write-baseline, then fill in justifications.",
            "entries": [e.to_json() for e in self.entries],
        }
        path.write_text(json.dumps(payload, indent=2) + "\n")

    def covers(self, finding: Finding, source_line: str) -> bool:
        text = source_line.strip()
        for e in self.entries:
            if (e.file == finding.path and e.code == finding.code
                    and e.match == text and e.used < e.count):
                e.used += 1
                return True
        return False

    def stale(self) -> List[BaselineEntry]:
        return [e for e in self.entries if e.used < e.count]


# ----------------------------------------------------------------------
# runner
# ----------------------------------------------------------------------
@dataclass
class LintReport:
    findings: List[Finding]            # every finding, classified
    errors: List[str]                  # unparsable files etc.
    stale_baseline: List[BaselineEntry]

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if f.status == "active"]

    def to_json(self) -> Dict:
        return {
            "schema": "simlint-report-v1",
            "active": len(self.active),
            "findings": [
                {"code": f.code, "file": f.path, "line": f.line,
                 "col": f.col, "message": f.message, "status": f.status}
                for f in self.findings],
            "stale_baseline": [e.to_json() for e in self.stale_baseline],
            "errors": self.errors,
        }


def find_repo_root(start: Path) -> Path:
    """Nearest ancestor holding a repo marker (.git / ruff.toml)."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if (cand / ".git").exists() or (cand / "ruff.toml").exists():
            return cand
    return cur


def _rules():
    # local import: rules imports core for Finding/ModuleInfo
    from repro.analysis import rules
    return rules.REGISTRY


def lint_text(text: str, path: str,
              select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one module given as source text under a virtual repo-relative
    ``path`` (rules scope themselves by path).  Inline suppressions apply;
    no baseline.  The primary entry point for rule fixtures/tests."""
    tree = ast.parse(text, filename=path)
    lines = text.splitlines()
    mod = ModuleInfo(path=path, tree=tree, lines=lines)
    sup = Suppressions(lines)
    found: List[Finding] = []
    for rule in _rules():
        if select and rule.code not in select:
            continue
        for f in rule.check(mod):
            found.append(f.with_status("suppressed") if sup.covers(f)
                         else f)
    found.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return found


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    # dedupe, keep order
    seen = set()
    out = []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def run_paths(paths: Sequence[Path], *, repo_root: Optional[Path] = None,
              baseline: Optional[Baseline] = None,
              select: Optional[Sequence[str]] = None) -> LintReport:
    """Lint every ``*.py`` under ``paths``; classify findings against the
    inline suppressions and the baseline."""
    root = repo_root or find_repo_root(paths[0] if paths else Path("."))
    findings: List[Finding] = []
    errors: List[str] = []
    for fpath in iter_py_files(paths):
        try:
            rel = fpath.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = fpath.as_posix()
        try:
            text = fpath.read_text()
            per_file = lint_text(text, rel, select=select)
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{rel}: {e}")
            continue
        if baseline is not None:
            lines = text.splitlines()
            classified = []
            for f in per_file:
                if f.status == "active" and baseline.covers(
                        f, lines[f.line - 1] if f.line <= len(lines)
                        else ""):
                    f = f.with_status("baselined")
                classified.append(f)
            per_file = classified
        findings.extend(per_file)
    stale = baseline.stale() if baseline is not None else []
    return LintReport(findings=findings, errors=errors,
                      stale_baseline=stale)


def make_baseline(report: LintReport, lines_of: Dict[str, List[str]],
                  why: str = "TODO: justify") -> Baseline:
    """Grandfather every active finding of ``report`` (used by
    ``--write-baseline``); justifications start as TODOs the author must
    fill in — the loader rejects empty ones, and CI loads the baseline."""
    counts: Dict[Tuple[str, str, str], int] = {}
    for f in report.active:
        src = lines_of.get(f.path, [])
        text = src[f.line - 1].strip() if f.line <= len(src) else ""
        counts[(f.path, f.code, text)] = counts.get(
            (f.path, f.code, text), 0) + 1
    entries = [BaselineEntry(file=p, code=c, match=m, why=why, count=n)
               for (p, c, m), n in sorted(counts.items())]
    return Baseline(entries)
