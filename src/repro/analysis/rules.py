"""simlint rules — the repo's simulator invariants as AST checks.

Four families, each born from a real incident class:

SIM1xx  RNG discipline
    SIM101  PRNG key reuse: the same key consumed by two ``jax.random``
            draws (or ``split``) with no rebinding between them.
    SIM102  ``PRNGKey(<literal>)`` in library code — seeds must flow
            from config so sweeps/tests control them.
    SIM103  ``np.random`` / stdlib ``random`` under ``src/repro/`` —
            host RNG streams are allowed only where a pinned draw
            schedule is documented (baseline / suppression).
    SIM104  RNG draw inside a Python-level branch: the draw *schedule*
            then depends on host data — the PR-5 mobility-bug shape
            (``advance_to(t1); advance_to(t2)`` consumed a different
            stream than ``advance_to(t2)``).

SIM2xx  host/device boundary (hot-path modules only)
    SIM201  ``.item()`` / ``.tolist()`` — implicit device→host sync.
    SIM202  ``np.asarray`` / ``np.array`` / ``jax.device_get`` — host
            materialisation; each hot-path use needs a justification.
    SIM203  ``float()/int()/bool()`` directly on a ``jnp``/``jax``
            expression — an implicit blocking transfer.

SIM3xx  jit purity (functions reachable from jit/vmap/scan/pallas roots)
    SIM301  ``print`` / ``breakpoint`` inside traced code.
    SIM302  wall-clock reads (``time.*`` / ``datetime.now``) — traced
            once, then frozen into the compiled program.
    SIM303  tracer/telemetry calls (``obs.CURRENT.span`` etc.) inside
            traced code — spans cannot measure inside a jit.
    SIM304  mutation of enclosing state (``global``/``nonlocal``,
            stores into free/parameter containers) — silently traced
            away or wrong under retracing.

SIM4xx  observability read-only (the PR-7 contract)
    SIM401  ``src/repro/obs`` importing simulator packages.
    SIM402  obs code calling state-mutating simulator APIs.

Every rule yields precise ``file:line:col`` findings; scoping decisions
(which paths a rule patrols) live here, next to the rule they belong to.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, ModuleInfo, in_hot_path

__all__ = ["REGISTRY", "Rule", "rule"]


class Rule:
    code = "SIM000"
    name = "abstract"
    doc = ""

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        raise NotImplementedError


REGISTRY: List[Rule] = []


def rule(cls):
    REGISTRY.append(cls())
    return cls


# ----------------------------------------------------------------------
# import alias resolution
# ----------------------------------------------------------------------
class Aliases:
    """Maps local names to canonical dotted module paths, so rules see
    ``jr.normal`` as ``jax.random.normal`` and know whether a bare
    ``random`` is the stdlib module or ``jax.random``."""

    def __init__(self, tree: ast.Module):
        self.names: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.names[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.names[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, expr: ast.expr) -> Optional[str]:
        """Canonical dotted path of a Name/Attribute chain, or None."""
        parts: List[str] = []
        node = expr
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.names.get(node.id, node.id)
        parts.append(base)
        return ".".join(reversed(parts))


def dotted(expr: ast.expr) -> Optional[str]:
    """Literal dotted text of a Name/Attribute chain (no alias mapping)."""
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


# jax.random draw functions that CONSUME a key (split also consumes: using
# a key after splitting it is the classic reuse bug).  fold_in and
# PRNGKey/key derive/create and do not consume.
JAX_CONSUMERS = {
    "ball", "bernoulli", "beta", "binomial", "bits", "categorical",
    "cauchy", "chisquare", "choice", "dirichlet", "double_sided_maxwell",
    "exponential", "f", "gamma", "generalized_normal", "geometric",
    "gumbel", "laplace", "loggamma", "logistic", "lognormal", "maxwell",
    "multivariate_normal", "normal", "orthogonal", "pareto", "permutation",
    "poisson", "rademacher", "randint", "rayleigh", "split", "t",
    "triangular", "truncated_normal", "uniform", "wald", "weibull_min",
}
# numpy Generator draw methods (receiver name must look like an rng)
NP_DRAW_METHODS = {
    "beta", "binomial", "bytes", "chisquare", "choice", "dirichlet",
    "exponential", "f", "gamma", "geometric", "gumbel", "integers",
    "laplace", "logistic", "lognormal", "multinomial",
    "multivariate_normal", "negative_binomial", "noncentral_chisquare",
    "normal", "pareto", "permutation", "permuted", "poisson", "power",
    "random", "rayleigh", "shuffle", "standard_cauchy",
    "standard_exponential", "standard_gamma", "standard_normal",
    "standard_t", "triangular", "uniform", "vonmises", "wald", "weibull",
    "zipf",
}


def _jax_random_member(aliases: Aliases, func: ast.expr) -> Optional[str]:
    """'normal' for a call target resolving to jax.random.normal, etc."""
    path = aliases.resolve(func)
    if path and path.startswith("jax.random."):
        member = path[len("jax.random."):]
        if "." not in member:
            return member
    return None


def _rng_method(func: ast.expr) -> Optional[Tuple[str, str]]:
    """(receiver, method) for ``<something rng-ish>.<draw-method>()``."""
    if not isinstance(func, ast.Attribute):
        return None
    recv = dotted(func.value)
    if recv is None:
        return None
    leaf = recv.rsplit(".", 1)[-1]
    if (leaf == "rng" or leaf.endswith("_rng") or leaf == "gen") \
            and func.attr in NP_DRAW_METHODS:
        return recv, func.attr
    return None


def _calls_in_order(node: ast.AST) -> List[ast.Call]:
    out: List[ast.Call] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            out.append(sub)
    out.sort(key=lambda c: (c.lineno, c.col_offset))
    return out


# ----------------------------------------------------------------------
# SIM101 — key reuse
# ----------------------------------------------------------------------
class _KeyState:
    """Per-scope key freshness, branch-aware (see _walk_stmts)."""

    def __init__(self):
        self.consumed: Dict[str, int] = {}   # key name -> line consumed

    def copy(self) -> "_KeyState":
        s = _KeyState()
        s.consumed = dict(self.consumed)
        return s

    def merge(self, other: "_KeyState") -> None:
        for k, ln in other.consumed.items():
            self.consumed.setdefault(k, ln)


@rule
class KeyReuse(Rule):
    code = "SIM101"
    name = "prng-key-reuse"
    doc = ("the same PRNG key is consumed by two jax.random calls with "
           "no split/fold_in rebinding in between")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        aliases = Aliases(mod.tree)
        findings: Dict[Tuple[int, str], Finding] = {}
        scopes: List[Sequence[ast.stmt]] = [mod.tree.body]
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append(node.body)
        for body in scopes:
            self._walk_stmts(body, _KeyState(), aliases, findings)
        for key in sorted(findings):
            f = findings[key]
            yield Finding(f.code, mod.path, f.line, f.col, f.message)

    # -- statement walker ------------------------------------------------
    def _walk_stmts(self, stmts: Sequence[ast.stmt], state: _KeyState,
                    aliases: Aliases,
                    findings: Dict[Tuple[int, str], Finding]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue                 # nested scopes walked separately
            if isinstance(stmt, ast.If):
                self._eval_expr(stmt.test, state, aliases, findings)
                s_then = state.copy()
                self._walk_stmts(stmt.body, s_then, aliases, findings)
                s_else = state.copy()
                self._walk_stmts(stmt.orelse, s_else, aliases, findings)
                state.consumed = dict(s_then.consumed)
                state.merge(s_else)
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                if isinstance(stmt, ast.While):
                    self._eval_expr(stmt.test, state, aliases, findings)
                else:
                    self._eval_expr(stmt.iter, state, aliases, findings)
                    self._store_target(stmt.target, state)
                # two passes catch draws that reuse a key across
                # iterations without rebinding it; findings dedupe by
                # (line, code) so the second pass adds no noise
                for _ in range(2):
                    self._walk_stmts(stmt.body, state, aliases, findings)
                self._walk_stmts(stmt.orelse, state, aliases, findings)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._eval_expr(item.context_expr, state, aliases,
                                    findings)
                    if item.optional_vars is not None:
                        self._store_target(item.optional_vars, state)
                self._walk_stmts(stmt.body, state, aliases, findings)
                continue
            if isinstance(stmt, ast.Try):
                self._walk_stmts(stmt.body, state, aliases, findings)
                for h in stmt.handlers:
                    self._walk_stmts(h.body, state.copy(), aliases,
                                     findings)
                self._walk_stmts(stmt.orelse, state, aliases, findings)
                self._walk_stmts(stmt.finalbody, state, aliases, findings)
                continue
            # plain statement: evaluate loads, then apply stores
            self._eval_expr(stmt, state, aliases, findings)
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.Assign, ast.AnnAssign,
                                    ast.AugAssign)):
                    targets = (sub.targets
                               if isinstance(sub, ast.Assign)
                               else [sub.target])
                    for t in targets:
                        self._store_target(t, state)
                elif isinstance(sub, ast.Delete):
                    for t in sub.targets:
                        self._store_target(t, state)
                elif isinstance(sub, ast.NamedExpr):
                    self._store_target(sub.target, state)

    def _store_target(self, target: ast.expr, state: _KeyState) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._store_target(elt, state)
            return
        if isinstance(target, ast.Starred):
            self._store_target(target.value, state)
            return
        name = dotted(target)
        if name is not None:
            state.consumed.pop(name, None)

    def _eval_expr(self, node: ast.AST, state: _KeyState, aliases: Aliases,
                   findings: Dict[Tuple[int, str], Finding]) -> None:
        for call in _calls_in_order(node):
            member = _jax_random_member(aliases, call.func)
            if member is None or member not in JAX_CONSUMERS:
                continue
            key_arg = self._key_arg(call)
            if key_arg is None:
                continue
            name = dotted(key_arg)
            if name is None:
                continue                # derived expression — fine
            prev = state.consumed.get(name)
            if prev is not None:
                fkey = (call.lineno, name)
                if fkey not in findings:
                    findings[fkey] = Finding(
                        self.code, "", call.lineno, call.col_offset,
                        f"PRNG key '{name}' reused (already consumed at "
                        f"line {prev}); split or fold_in first")
            else:
                state.consumed[name] = call.lineno

    @staticmethod
    def _key_arg(call: ast.Call) -> Optional[ast.expr]:
        if call.args:
            return call.args[0]
        for kw in call.keywords:
            if kw.arg == "key":
                return kw.value
        return None


# ----------------------------------------------------------------------
# SIM102 — literal PRNGKey in library code
# ----------------------------------------------------------------------
@rule
class LiteralKey(Rule):
    code = "SIM102"
    name = "literal-prng-seed"
    doc = ("jax.random.PRNGKey(<literal>) in library code — seeds must "
           "come from config/arguments (tests and examples are exempt)")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_src_repro() or mod.is_testish():
            return
        aliases = Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            member = _jax_random_member(aliases, node.func)
            if member not in ("PRNGKey", "key"):
                continue
            if node.args and isinstance(node.args[0], ast.Constant):
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"jax.random.{member}({node.args[0].value!r}) with a "
                    f"literal seed in library code; plumb the seed from "
                    f"config")


# ----------------------------------------------------------------------
# SIM103 — host RNG under src/repro
# ----------------------------------------------------------------------
@rule
class HostRng(Rule):
    code = "SIM103"
    name = "host-rng-in-library"
    doc = ("np.random / stdlib random under src/repro — host RNG streams "
           "need a documented, pinned draw schedule (baseline each one)")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_src_repro():
            return
        aliases = Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                modname = (node.module or "" if
                           isinstance(node, ast.ImportFrom)
                           else "")
                names = ([a.name for a in node.names]
                         if isinstance(node, ast.Import) else [])
                if modname == "random" or "random" in names:
                    yield Finding(
                        self.code, mod.path, node.lineno,
                        node.col_offset,
                        "stdlib 'random' imported in library code; use "
                        "a seeded np.random.Generator or jax.random")
                continue
            if not isinstance(node, ast.Call):
                continue
            path = aliases.resolve(node.func)
            if path is None:
                continue
            if path.startswith("numpy.random.") or \
                    path.startswith("np.random."):
                member = path.rsplit(".", 1)[-1]
                kind = ("module-level numpy RNG (shared global state)"
                        if member not in ("default_rng", "Generator",
                                          "SeedSequence", "PCG64")
                        else "host RNG stream")
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"{kind}: np.random.{member} in library code — "
                    f"host draws need a pinned, documented schedule")
            elif path.startswith("random.") and \
                    aliases.names.get("random", "random") == "random":
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"stdlib {path} in library code; use a seeded "
                    f"np.random.Generator or jax.random")


# ----------------------------------------------------------------------
# SIM104 — draw schedule branches on Python data
# ----------------------------------------------------------------------
@rule
class BranchedDraw(Rule):
    code = "SIM104"
    name = "data-dependent-draw-schedule"
    doc = ("an RNG draw inside a Python-level branch makes the draw "
           "*schedule* depend on host data (the PR-5 bug shape); hoist "
           "the draw to a fixed schedule or derive keys via fold_in")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_src_repro():
            return
        aliases = Aliases(mod.tree)
        yield from self._walk(mod, aliases, mod.tree.body, 0)

    def _walk(self, mod: ModuleInfo, aliases: Aliases,
              stmts: Sequence[ast.stmt], depth: int) -> Iterator[Finding]:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._walk(mod, aliases, stmt.body, 0)
                continue
            if isinstance(stmt, ast.ClassDef):
                yield from self._walk(mod, aliases, stmt.body, depth)
                continue
            if isinstance(stmt, ast.If):
                yield from self._exprs(mod, aliases, [stmt.test], depth)
                yield from self._walk(mod, aliases, stmt.body, depth + 1)
                yield from self._walk(mod, aliases, stmt.orelse,
                                      depth + 1)
                continue
            if isinstance(stmt, ast.While):
                yield from self._exprs(mod, aliases, [stmt.test],
                                       depth + 1)
                yield from self._walk(mod, aliases, stmt.body, depth + 1)
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                yield from self._exprs(mod, aliases, [stmt.iter], depth)
                yield from self._walk(mod, aliases, stmt.body, depth)
                yield from self._walk(mod, aliases, stmt.orelse, depth)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                yield from self._walk(mod, aliases, stmt.body, depth)
                continue
            if isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody):
                    yield from self._walk(mod, aliases, block, depth)
                for h in stmt.handlers:
                    yield from self._walk(mod, aliases, h.body, depth + 1)
                continue
            yield from self._exprs(mod, aliases, [stmt], depth)

    def _exprs(self, mod: ModuleInfo, aliases: Aliases,
               nodes: Sequence[ast.AST], depth: int) -> Iterator[Finding]:
        for node in nodes:
            for call in _calls_in_order(node):
                extra = self._cond_depth(node, call)
                if depth + extra == 0:
                    continue
                member = _jax_random_member(aliases, call.func)
                is_draw = (member in JAX_CONSUMERS and member != "split"
                           ) or _rng_method(call.func) is not None
                if not is_draw:
                    continue
                what = (f"jax.random.{member}" if member
                        else dotted(call.func))
                yield Finding(
                    self.code, mod.path, call.lineno, call.col_offset,
                    f"{what} draw inside a conditional: the RNG draw "
                    f"schedule now depends on Python-level state")

    @staticmethod
    def _cond_depth(root: ast.AST, call: ast.Call) -> int:
        """Extra conditional nesting of ``call`` *within* a statement:
        ternaries and comprehension ifs."""
        depth = 0
        for node in ast.walk(root):
            if isinstance(node, ast.IfExp):
                for branch in (node.body, node.orelse):
                    if call in ast.walk(branch):
                        depth += 1
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                for gen in node.generators:
                    for cond in gen.ifs:
                        if call in ast.walk(cond):
                            depth += 1
        return depth


# ----------------------------------------------------------------------
# SIM2xx — host/device boundary in hot-path modules
# ----------------------------------------------------------------------
def _imports_jax(mod: ModuleInfo) -> bool:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            if any(a.name == "jax" or a.name.startswith("jax.")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "jax":
                return True
    return False


@rule
class HostSyncMethods(Rule):
    code = "SIM201"
    name = "implicit-host-sync-method"
    doc = (".item()/.tolist() in a hot-path module — implicit "
           "device-to-host sync; move it off the per-event path or "
           "justify with a suppression")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not in_hot_path(mod.path) or not _imports_jax(mod):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist"):
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f".{node.func.attr}() blocks on device work in a "
                    f"hot-path module")


@rule
class HostMaterialise(Rule):
    code = "SIM202"
    name = "host-materialisation"
    doc = ("np.asarray / np.array / jax.device_get in a hot-path module "
           "pulls device values to host when fed a jax array; every use "
           "needs a justification (suppression) or a redesign")

    TARGETS = ("numpy.asarray", "numpy.array", "np.asarray", "np.array",
               "jax.device_get")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not in_hot_path(mod.path) or not _imports_jax(mod):
            return
        aliases = Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            path = aliases.resolve(node.func)
            if path in self.TARGETS:
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"{path} in a hot-path module — host "
                    f"materialisation; justify (host-only value) or "
                    f"keep it on device")


@rule
class ScalarCoercion(Rule):
    code = "SIM203"
    name = "scalar-coercion-of-device-value"
    doc = ("float()/int()/bool() wrapped directly around a jnp/jax "
           "expression is an implicit blocking device sync (static "
           "metadata reads — .shape/.ndim/.dtype — are exempt)")

    METADATA = ("shape", "ndim", "dtype", "itemsize")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not in_hot_path(mod.path) or not _imports_jax(mod):
            return
        aliases = Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.args):
                continue
            # anything consumed through a .shape/.ndim/... attribute is a
            # static metadata read, not a device value
            meta_subtrees = [
                a.value for a in ast.walk(node.args[0])
                if isinstance(a, ast.Attribute) and a.attr in self.METADATA]
            for sub in ast.walk(node.args[0]):
                if isinstance(sub, ast.Call):
                    if any(sub in ast.walk(m) for m in meta_subtrees):
                        continue
                    path = aliases.resolve(sub.func)
                    if path and (path.startswith("jax.numpy.")
                                 or path.startswith("jnp.")
                                 or path.startswith("jax.")):
                        yield Finding(
                            self.code, mod.path, node.lineno,
                            node.col_offset,
                            f"{node.func.id}() directly on a "
                            f"{path.split('.')[0]} expression — "
                            f"implicit device sync")
                        break
            else:
                continue


# ----------------------------------------------------------------------
# SIM3xx — jit purity
# ----------------------------------------------------------------------
# transforms whose function arguments are traced (arg indices to inspect;
# None = every positional arg)
TRACED_CALLS: Dict[str, Optional[Tuple[int, ...]]] = {
    "jax.jit": (0,), "jax.vmap": (0,), "jax.pmap": (0,),
    "jax.grad": (0,), "jax.value_and_grad": (0,), "jax.jacfwd": (0,),
    "jax.jacrev": (0,), "jax.hessian": (0,), "jax.checkpoint": (0,),
    "jax.remat": (0,), "jax.lax.scan": (0,), "jax.lax.map": (0,),
    "jax.lax.while_loop": (0, 1), "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2), "jax.lax.switch": None,
    "jax.lax.associative_scan": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
}
TRACED_DECORATORS = {"jax.jit", "jax.vmap", "jax.pmap", "jax.checkpoint",
                     "jax.remat"}


class _FnNode:
    def __init__(self, node, qual: str):
        self.node = node
        self.qual = qual
        self.calls: Set[str] = set()      # simple callee names
        self.root = False


def _collect_jit_graph(mod: ModuleInfo, aliases: Aliases
                       ) -> Tuple[Dict[str, List[_FnNode]],
                                  List[_FnNode], List[ast.Lambda]]:
    """Module-wide function defs, jit roots, and traced lambdas."""
    by_name: Dict[str, List[_FnNode]] = {}
    fns: List[_FnNode] = []

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef,
                                  ast.AsyncFunctionDef)):
                fn = _FnNode(child, f"{prefix}{child.name}")
                fns.append(fn)
                by_name.setdefault(child.name, []).append(fn)
                visit(child, f"{prefix}{child.name}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)

    visit(mod.tree, "")

    for fn in fns:
        for sub in ast.walk(fn.node):
            if isinstance(sub, ast.Call):
                if isinstance(sub.func, ast.Name):
                    fn.calls.add(sub.func.id)
                elif isinstance(sub.func, ast.Attribute) \
                        and isinstance(sub.func.value, ast.Name) \
                        and sub.func.value.id == "self":
                    fn.calls.add(sub.func.attr)

    roots: List[_FnNode] = []
    lambdas: List[ast.Lambda] = []

    def resolve_traced(path: Optional[str]) -> Optional[str]:
        if path is None:
            return None
        norm = path.replace("lax.", "jax.lax.") \
            if path.startswith("lax.") else path
        if norm in TRACED_CALLS:
            return norm
        # pallas aliases: pl.pallas_call, pallas_call
        if norm.endswith("pallas_call"):
            return "jax.experimental.pallas.pallas_call"
        if norm.endswith("shard_map"):
            return "jax.experimental.shard_map.shard_map"
        return None

    def mark(name_node: ast.expr) -> None:
        if isinstance(name_node, ast.Lambda):
            lambdas.append(name_node)
            return
        if isinstance(name_node, ast.Name):
            for fn in by_name.get(name_node.id, []):
                fn.root = True
        elif isinstance(name_node, ast.Attribute) \
                and isinstance(name_node.value, ast.Name) \
                and name_node.value.id == "self":
            for fn in by_name.get(name_node.attr, []):
                fn.root = True

    # decorators
    for fn in fns:
        if not isinstance(fn.node, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
            continue
        for dec in fn.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            path = aliases.resolve(target)
            if path == "functools.partial" and isinstance(dec, ast.Call) \
                    and dec.args:
                path = aliases.resolve(dec.args[0])
            if resolve_traced(path) or path in TRACED_DECORATORS:
                fn.root = True

    # call sites
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        path = resolve_traced(aliases.resolve(node.func))
        if path is None:
            # functools.partial(jax.jit, f) style
            p = aliases.resolve(node.func)
            if p == "functools.partial" and node.args:
                inner = resolve_traced(aliases.resolve(node.args[0]))
                if inner is not None and len(node.args) > 1:
                    mark(node.args[1])
            continue
        arg_idx = TRACED_CALLS.get(path, (0,))
        args = node.args
        if arg_idx is None:
            for a in args:
                mark(a)
        else:
            for i in arg_idx:
                if i < len(args):
                    mark(args[i])

    # closure: reachable = roots + transitively called module functions
    work = [fn for fn in fns if fn.root]
    for fn in work:
        roots.append(fn)
    seen = {id(fn) for fn in work}
    while work:
        fn = work.pop()
        for callee in fn.calls:
            for cand in by_name.get(callee, []):
                if id(cand) not in seen:
                    seen.add(id(cand))
                    cand.root = True
                    work.append(cand)
                    roots.append(cand)
    return by_name, roots, lambdas


def _local_bindings(fn_node) -> Set[str]:
    """Names bound by simple assignment/for/with inside the function
    (parameters excluded — mutating a parameter container leaks out)."""
    bound: Set[str] = set()
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            bound.add(sub.id)
        elif isinstance(sub, (ast.For, ast.AsyncFor)):
            for t in ast.walk(sub.target):
                if isinstance(t, ast.Name):
                    bound.add(t.id)
        elif isinstance(sub, ast.comprehension):
            for t in ast.walk(sub.target):
                if isinstance(t, ast.Name):
                    bound.add(t.id)
    return bound


def _own_body(fn_node) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs (they are
    separate graph nodes)."""
    stack: List[ast.AST] = list(fn_node.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            stack.append(child)


MUTATOR_METHODS = {"append", "extend", "add", "update", "pop", "clear",
                   "insert", "remove", "setdefault", "popitem",
                   "discard", "sort", "reverse"}


class _JitPurityBase(Rule):
    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        aliases = Aliases(mod.tree)
        _, reachable, lambdas = _collect_jit_graph(mod, aliases)
        emitted: Set[Tuple[str, int]] = set()
        for fn in reachable:
            for f in self.check_fn(mod, aliases, fn.node, fn.qual,
                                   list(_own_body(fn.node)),
                                   _local_bindings(fn.node)):
                k = (f.code, f.line)
                if k not in emitted:
                    emitted.add(k)
                    yield f
        for lam in lambdas:
            body = list(ast.walk(lam.body))
            for f in self.check_fn(mod, aliases, lam, "<lambda>", body,
                                   set()):
                k = (f.code, f.line)
                if k not in emitted:
                    emitted.add(k)
                    yield f

    def check_fn(self, mod, aliases, fn_node, qual, body, local):
        raise NotImplementedError


@rule
class JitPrint(_JitPurityBase):
    code = "SIM301"
    name = "print-in-traced-code"
    doc = ("print/breakpoint inside a jit/vmap/scan-reachable function "
           "runs at trace time only; use jax.debug.print")

    def check_fn(self, mod, aliases, fn_node, qual, body, local):
        for node in body:
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in ("print", "breakpoint") \
                    and node.func.id not in local:
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"{node.func.id}() inside jit-traced '{qual}' — "
                    f"runs once at trace time; use jax.debug.print")


@rule
class JitClock(_JitPurityBase):
    code = "SIM302"
    name = "wall-clock-in-traced-code"
    doc = ("time.*/datetime.now inside traced code is frozen at trace "
           "time — time the dispatch outside, or use obs.device_call")

    CLOCKS = {"time.time", "time.perf_counter", "time.monotonic",
              "time.process_time", "time.time_ns", "time.sleep",
              "datetime.datetime.now", "datetime.datetime.utcnow"}

    def check_fn(self, mod, aliases, fn_node, qual, body, local):
        for node in body:
            if isinstance(node, ast.Call):
                path = aliases.resolve(node.func)
                if path in self.CLOCKS:
                    yield Finding(
                        self.code, mod.path, node.lineno,
                        node.col_offset,
                        f"{path}() inside jit-traced '{qual}' is "
                        f"evaluated once at trace time")


@rule
class JitTracer(_JitPurityBase):
    code = "SIM303"
    name = "telemetry-in-traced-code"
    doc = ("obs tracer spans/counters inside traced code measure trace "
           "time, not run time — wrap the *dispatch* instead")

    METHODS = {"span", "add", "device_call", "counter"}

    def check_fn(self, mod, aliases, fn_node, qual, body, local):
        for node in body:
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self.METHODS):
                continue
            recv = dotted(node.func.value) or ""
            parts = recv.split(".")
            if "CURRENT" in parts or parts[0] in ("obs", "tracer") \
                    or recv == "tr":
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"tracer call {recv}.{node.func.attr}() inside "
                    f"jit-traced '{qual}' — instrument the dispatch, "
                    f"not the traced body")


@rule
class JitMutation(_JitPurityBase):
    code = "SIM304"
    name = "state-mutation-in-traced-code"
    doc = ("global/nonlocal or stores into enclosing/parameter "
           "containers inside traced code are silently traced away "
           "or wrong under retracing (Pallas Ref params — '*_ref' "
           "names — are exempt: Ref stores ARE the kernel output)")

    @staticmethod
    def _is_pallas_ref(name: str) -> bool:
        return name.endswith("_ref") or name in ("o_ref", "out_ref",
                                                 "ref")

    def check_fn(self, mod, aliases, fn_node, qual, body, local):
        for node in body:
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kw = ("global" if isinstance(node, ast.Global)
                      else "nonlocal")
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f"'{kw} {', '.join(node.names)}' inside jit-traced "
                    f"'{qual}' mutates enclosing state")
                continue
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, (ast.Subscript, ast.Attribute)):
                        root = t
                        while isinstance(root, (ast.Subscript,
                                                ast.Attribute)):
                            root = root.value
                        name = (root.id if isinstance(root, ast.Name)
                                else None)
                        if name is not None and name not in local \
                                and not self._is_pallas_ref(name):
                            yield Finding(
                                self.code, mod.path, t.lineno,
                                t.col_offset,
                                f"store into non-local container "
                                f"'{name}' inside jit-traced '{qual}' "
                                f"— traced functions must be pure")
                continue
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATOR_METHODS \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id not in local:
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f".{node.func.attr}() on enclosing-scope "
                    f"'{node.func.value.id}' inside jit-traced "
                    f"'{qual}' — traced functions must be pure")


# ----------------------------------------------------------------------
# SIM4xx — observability read-only
# ----------------------------------------------------------------------
OBS_ALLOWED_IMPORTS = ("repro.obs", "repro.utils", "repro.config",
                       "repro.analysis")


@rule
class ObsImports(Rule):
    code = "SIM401"
    name = "obs-imports-simulator"
    doc = ("src/repro/obs must not import simulator packages — the "
           "telemetry layer is read-only by construction (PR-7 "
           "contract); pass objects in, do not reach out")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_obs():
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                mods = [(a.name, node) for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                mods = [(node.module or "", node)]
            else:
                continue
            for name, n in mods:
                if name.startswith("repro") and not any(
                        name == ok or name.startswith(ok + ".")
                        for ok in OBS_ALLOWED_IMPORTS):
                    yield Finding(
                        self.code, mod.path, n.lineno, n.col_offset,
                        f"obs module imports simulator package "
                        f"'{name}' — the telemetry layer must stay "
                        f"read-only/import-free of the simulator")


MUTATING_SIM_API = {
    "on_arrival", "on_arrival_batch", "on_round_batch", "advance_to",
    "handover", "cloud_sync", "step", "step_many", "sample_fading",
    "sample_fading_batch", "make_servers", "pre_requeue",
    "bind_link_budget", "round_update", "compute_payloads",
    "compute_payloads_stacked",
}


@rule
class ObsMutates(Rule):
    code = "SIM402"
    name = "obs-calls-simulator-mutator"
    doc = ("obs code calling a state-mutating simulator API (advance_to,"
           " on_arrival, sample_fading, ...) breaks the read-only "
           "contract: tracing must never perturb a trajectory")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if not mod.in_obs():
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATING_SIM_API:
                yield Finding(
                    self.code, mod.path, node.lineno, node.col_offset,
                    f".{node.func.attr}() called from the observability "
                    f"layer — obs is read-only; it may look, not touch")
