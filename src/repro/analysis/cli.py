"""simlint command line.

Usage (repo root):

    PYTHONPATH=src python scripts/simlint.py src [benchmarks examples ...]
    PYTHONPATH=src python -m repro.analysis src --report simlint-report.json

Exit status: 0 when no *active* (unsuppressed, unbaselined) findings and
no stale baseline entries; 1 otherwise; 2 on usage/baseline errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.core import (
    DEFAULT_BASELINE_NAME,
    Baseline,
    LintReport,
    find_repo_root,
    iter_py_files,
    make_baseline,
    run_paths,
)


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="simlint",
        description="repo-specific invariant checker (RNG discipline, "
                    "host/device boundaries, jit purity, obs read-only)")
    p.add_argument("paths", nargs="*", type=Path,
                   help="files or directories to lint")
    p.add_argument("--select", metavar="CODES",
                   help="comma-separated rule codes to run (default all)")
    p.add_argument("--baseline", type=Path, default=None,
                   help=f"baseline JSON (default <repo>/"
                        f"{DEFAULT_BASELINE_NAME} when present)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore any baseline file")
    p.add_argument("--write-baseline", action="store_true",
                   help="grandfather all active findings into the "
                        "baseline file (justifications start as TODO)")
    p.add_argument("--report", type=Path, default=None,
                   help="write a JSON diagnostic report here")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="only print the summary line")
    return p


def _print_rules() -> None:
    from repro.analysis.rules import REGISTRY
    for r in REGISTRY:
        print(f"{r.code}  {r.name}")
        print(f"        {r.doc}")


def main(argv: Optional[List[str]] = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        _print_rules()
        return 0
    if not args.paths:
        _parser().error("the following arguments are required: paths")

    repo_root = find_repo_root(args.paths[0])
    baseline_path = args.baseline or repo_root / DEFAULT_BASELINE_NAME
    baseline = None
    if not args.no_baseline and not args.write_baseline \
            and baseline_path.exists():
        try:
            baseline = Baseline.load(baseline_path)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            print(f"simlint: bad baseline: {e}", file=sys.stderr)
            return 2

    select = ([c.strip().upper() for c in args.select.split(",")]
              if args.select else None)
    report = run_paths(args.paths, repo_root=repo_root,
                       baseline=baseline, select=select)

    if args.write_baseline:
        lines_of = {}
        for f in iter_py_files(args.paths):
            try:
                rel = f.resolve().relative_to(repo_root).as_posix()
            except ValueError:
                rel = f.as_posix()
            lines_of[rel] = f.read_text().splitlines()
        make_baseline(report, lines_of).save(baseline_path)
        print(f"simlint: wrote {len(report.active)} entries to "
              f"{baseline_path}; fill in every 'why' before committing")
        return 0

    return _emit(report, args)


def _emit(report: LintReport, args) -> int:
    active = report.active
    if not args.quiet:
        for f in active:
            print(f.render())
        for e in report.errors:
            print(f"simlint: error: {e}", file=sys.stderr)
        for entry in report.stale_baseline:
            print(f"simlint: stale baseline entry: {entry.file} "
                  f"{entry.code} ({entry.match!r}) — prune it",
                  file=sys.stderr)

    if args.report is not None:
        args.report.write_text(
            json.dumps(report.to_json(), indent=2) + "\n")

    n_sup = sum(1 for f in report.findings if f.status == "suppressed")
    n_base = sum(1 for f in report.findings if f.status == "baselined")
    print(f"simlint: {len(active)} active, {n_sup} suppressed, "
          f"{n_base} baselined, {len(report.stale_baseline)} stale "
          f"baseline entries")
    bad = bool(active) or bool(report.stale_baseline) \
        or bool(report.errors)
    return 1 if bad else 0


if __name__ == "__main__":       # pragma: no cover
    sys.exit(main())
