"""Minimal pure-pytree optimizers (no optax dependency).

``Optimizer`` is an (init, update) pair operating on arbitrary pytrees.
``update`` returns (new_params, new_state).  Learning rate is passed at call
time so schedules stay outside the optimizer state.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree_norm


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]   # (grads, state, params, lr)
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    """Global-norm gradient clipping; returns (clipped, pre_clip_norm)."""
    g_norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g_norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), g_norm


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update, "sgd")


def momentum(mu: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def update(grads, state, params, lr):
        m = jax.tree.map(lambda mi, g: mu * mi + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            step = jax.tree.map(lambda mi, g: mu * mi + g.astype(jnp.float32),
                                m, grads)
        else:
            step = m
        new = jax.tree.map(lambda p, s: (p - lr * s).astype(p.dtype),
                           params, step)
        return new, {"m": m}

    return Optimizer(init, update, "momentum")


def adam(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(state_dtype),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(state_dtype)),
            state["v"], grads)
        bc1 = 1.0 - jnp.power(b1, tf)
        bc2 = 1.0 - jnp.power(b2, tf)

        def step(p, mi, vi):
            mh = mi / bc1
            vh = vi / bc2
            upd = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(state_dtype)
            return (p.astype(state_dtype) - lr * upd).astype(p.dtype)

        new = jax.tree.map(step, params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adam")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum(**{k: v for k, v in kw.items() if k in ("mu", "nesterov")})
    if name == "adam":
        keys = ("b1", "b2", "eps", "weight_decay", "state_dtype")
        return adam(**{k: v for k, v in kw.items() if k in keys})
    raise ValueError(f"unknown optimizer {name!r}")
