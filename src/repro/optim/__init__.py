from repro.optim.optimizers import (
    Optimizer,
    adam,
    clip_by_global_norm,
    make_optimizer,
    momentum,
    sgd,
)
from repro.optim.schedule import constant, warmup_cosine

__all__ = [
    "Optimizer",
    "adam",
    "clip_by_global_norm",
    "constant",
    "make_optimizer",
    "momentum",
    "sgd",
    "warmup_cosine",
]
