from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, make_optimizer, clip_by_global_norm,
)
from repro.optim.schedule import warmup_cosine, constant
