"""Minimal stand-in for the ``hypothesis`` property-testing API.

The tier-1 suite uses a small slice of hypothesis (``given`` / ``settings`` /
``strategies.integers|floats|lists|composite``).  On containers without the
real package, tests fall back to this module: each strategy draws
deterministic pseudo-random examples from a fixed-seed generator and
``given`` simply re-runs the test body ``max_examples`` times.  No shrinking,
no example database — just enough to keep the property tests exercising the
same input space on a clean machine.

Usage (in tests)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # clean container
        from repro.utils.hypofallback import given, settings, strategies as st
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, Sequence

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_EXAMPLES = 10


class SearchStrategy:
    """A value generator: ``example(rng)`` draws one example."""

    def __init__(self, sample: Callable[[np.random.Generator], Any]):
        self._sample = sample

    def example(self, rng: np.random.Generator) -> Any:
        return self._sample(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 100) -> "SearchStrategy":
        def sample(rng):
            for _ in range(max_tries):
                x = self._sample(rng)
                if pred(x):
                    return x
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(sample)


class strategies:
    """Namespace mimicking ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, **_: Any) -> SearchStrategy:
        lo, hi = float(min_value), float(max_value)

        def sample(rng):
            # hit the endpoints occasionally, like hypothesis does
            r = rng.random()
            if r < 0.05:
                return lo
            if r < 0.10:
                return hi
            return float(rng.uniform(lo, hi))
        return SearchStrategy(sample)

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq: Sequence[Any]) -> SearchStrategy:
        items = list(seq)
        return SearchStrategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def lists(elements: SearchStrategy, min_size: int = 0,
              max_size: int = 10, **_: Any) -> SearchStrategy:
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return SearchStrategy(sample)

    @staticmethod
    def composite(fn: Callable[..., Any]) -> Callable[..., SearchStrategy]:
        def make(*args: Any, **kw: Any) -> SearchStrategy:
            def sample(rng):
                return fn(lambda strat: strat.example(rng), *args, **kw)
            return SearchStrategy(sample)
        return make


class _AttrSink:
    def __getattr__(self, name: str) -> str:  # pragma: no cover
        return name


# attribute sink so ``suppress_health_check=[HealthCheck.too_slow]`` parses
HealthCheck = _AttrSink()


def given(*strats: SearchStrategy, **kwstrats: SearchStrategy):
    """Re-run the test over ``max_examples`` deterministic draws.

    The returned wrapper takes NO parameters (all strategy-bound arguments
    are filled here) so pytest does not mistake them for fixtures — matching
    how real hypothesis rewrites the signature.
    """
    def deco(fn: Callable) -> Callable:
        def wrapper():
            n = getattr(wrapper, "_hypofallback_max_examples",
                        _DEFAULT_EXAMPLES)
            # crc32, not hash(): str hashing is salted per process and would
            # break the docstring's cross-run determinism promise
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                args = [s.example(rng) for s in strats]
                kwargs = {k: s.example(rng) for k, s in kwstrats.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        # settings() applied *inside* given: carry the attribute over
        wrapper.__dict__.update(getattr(fn, "__dict__", {}))
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline: Any = None,
             **_: Any):
    """Record ``max_examples``; ``deadline`` and the rest are ignored."""
    def deco(fn: Callable) -> Callable:
        fn._hypofallback_max_examples = max_examples
        return fn
    return deco
