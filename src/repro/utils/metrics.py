"""JSONL metrics logging (training-run observability substrate).

Append-only, crash-safe (one flush per record), dependency-free:

    logger = MetricsLogger("runs/exp1")
    logger.log(step=10, loss=2.31, grad_norm=0.8)
    ...
    rows = read_metrics("runs/exp1/metrics.jsonl")
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional


class MetricsLogger:
    def __init__(self, run_dir: str, filename: str = "metrics.jsonl",
                 meta: Optional[Dict[str, Any]] = None):
        os.makedirs(run_dir, exist_ok=True)
        self.path = os.path.join(run_dir, filename)
        self._f = open(self.path, "a", buffering=1)
        self._t0 = time.time()
        if meta:
            self._write({"_meta": _plain(meta)})

    def log(self, step: Optional[int] = None, **values) -> None:
        rec: Dict[str, Any] = {"t": round(time.time() - self._t0, 4)}
        if step is not None:
            rec["step"] = int(step)
        rec.update({k: _plain(v) for k, v in values.items()})
        self._write(rec)

    def _write(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# non-0-d arrays at or under this many elements serialise as (nested)
# lists; larger ones as a shape/dtype stub — a [16k]-UE vector logged by
# accident must not explode the JSONL
ARRAY_ELEMS_CAP = 64


def _plain(v: Any) -> Any:
    """Coerce jax/numpy scalars and containers to JSON-safe python."""
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()
    if hasattr(v, "ndim") and hasattr(v, "tolist"):
        # non-0-d ndarray/jax array: used to fall through un-coerced and
        # crash json.dumps — coerce small ones to lists, summarize big
        if int(np_size(v)) <= ARRAY_ELEMS_CAP:
            return _plain(v.tolist())
        return {"shape": [int(s) for s in v.shape],
                "dtype": str(v.dtype), "size": int(np_size(v))}
    if isinstance(v, dict):
        return {k: _plain(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    if isinstance(v, float) and v != v:          # NaN → null
        return None
    return v


def np_size(v: Any) -> int:
    size = getattr(v, "size", None)
    if size is None:                             # duck-typed array
        size = 1
        for s in v.shape:
            size *= int(s)
    return int(size)


def read_metrics(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
