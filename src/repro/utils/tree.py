"""Pytree arithmetic helpers used across the framework.

All helpers are pure and jit-friendly; they operate leaf-wise on arbitrary
pytrees of arrays (model parameters, gradients, optimizer state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    """Leaf-wise a + b."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leaf-wise a - b."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Leaf-wise s * a for scalar s."""
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """Leaf-wise alpha * x + y (BLAS axpy)."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Sum over all leaves of <a_i, b_i> (flattened inner product)."""
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_norm(a):
    """Global L2 norm over the whole pytree."""
    sq = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.asarray(0.0, jnp.float32)))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar parameters in the pytree (python int)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    """Total number of bytes of the pytree (python int)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    """Cast every floating leaf to `dtype`; leave integer leaves alone."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, a)


# ---------------------------------------------------------------------------
# Cached flatten/unflatten — the single-buffer path behind the unified
# aggregation API (kernels/stale_aggregate.py)
# ---------------------------------------------------------------------------

class TreeFlattener:
    """Flatten a pytree into ONE contiguous f32 vector and back.

    The treedef plus per-leaf (shape, dtype, offset) metadata are computed
    once and cached by structure (``TreeFlattener.for_tree``), so repeated
    aggregation calls — one per simulated round — pay only the concat, not
    re-deriving structure on the host.  All methods are jit-traceable.
    """

    _CACHE: dict = {}

    def __init__(self, treedef, shapes, dtypes):
        self.treedef = treedef
        self.shapes = tuple(shapes)
        self.dtypes = tuple(dtypes)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        offs = np.cumsum((0,) + self.sizes)
        self.offsets = tuple(int(o) for o in offs[:-1])
        self.size = int(offs[-1])

    # -- construction ------------------------------------------------------
    @classmethod
    def for_tree(cls, tree) -> "TreeFlattener":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(x.shape) for x in leaves)
        dtypes = tuple(jnp.asarray(x).dtype for x in leaves)
        key = (treedef, shapes, dtypes)
        hit = cls._CACHE.get(key)
        if hit is None:
            hit = cls._CACHE[key] = cls(treedef, shapes, dtypes)
        return hit

    # -- flatten -----------------------------------------------------------
    def flatten(self, tree, dtype=jnp.float32):
        """tree → single [size] vector (one concat buffer)."""
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [jnp.ravel(jnp.asarray(x)).astype(dtype) for x in leaves])

    def flatten_stacked(self, tree, dtype=jnp.float32):
        """Tree whose leaves carry a leading axis C → [C, size] matrix."""
        leaves = self.treedef.flatten_up_to(tree)
        c = jnp.asarray(leaves[0]).shape[0]
        return jnp.concatenate(
            [jnp.reshape(jnp.asarray(x), (c, -1)).astype(dtype)
             for x in leaves], axis=1)

    # -- unflatten ---------------------------------------------------------
    def unflatten(self, flat, dtype=None):
        """[size] vector → tree; leaves restored to their original dtypes
        (or all cast to ``dtype`` when given)."""
        leaves = [
            jnp.reshape(flat[o:o + s], shape).astype(dtype or dt)
            for o, s, shape, dt in zip(self.offsets, self.sizes,
                                       self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)
