"""Pytree arithmetic helpers used across the framework.

All helpers are pure and jit-friendly; they operate leaf-wise on arbitrary
pytrees of arrays (model parameters, gradients, optimizer state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Leaf-wise a + b."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leaf-wise a - b."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Leaf-wise s * a for scalar s."""
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """Leaf-wise alpha * x + y (BLAS axpy)."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Sum over all leaves of <a_i, b_i> (flattened inner product)."""
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_norm(a):
    """Global L2 norm over the whole pytree."""
    sq = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.asarray(0.0, jnp.float32)))


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar parameters in the pytree (python int)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    """Total number of bytes of the pytree (python int)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    """Cast every floating leaf to `dtype`; leave integer leaves alone."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, a)
