from repro.mobility.models import (Area, GaussMarkov, MobilityModel,
                                   RandomWaypoint, StaticMobility,
                                   get_mobility)
from repro.mobility.multicell import MultiCellNetwork, cell_layout
