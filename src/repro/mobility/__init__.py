from repro.mobility.models import (
    Area,
    GaussMarkov,
    MobilityModel,
    RandomWaypoint,
    StaticMobility,
    get_mobility,
)
from repro.mobility.multicell import MultiCellNetwork, cell_layout

__all__ = [
    "Area",
    "GaussMarkov",
    "MobilityModel",
    "MultiCellNetwork",
    "RandomWaypoint",
    "StaticMobility",
    "cell_layout",
    "get_mobility",
]
