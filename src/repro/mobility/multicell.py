"""Multi-cell mobile edge network: several BSs, moving UEs, handovers.

Generalises ``wireless.channel.EdgeNetwork`` (one static cell) to a hex-ish
grid of base stations with UEs that move under a ``MobilityModel`` and
associate under a pluggable policy.  The channel API (``sample_fading`` /
``channel`` / ``channels`` / ``mean_rates`` / ``distances``) is a drop-in
superset of ``EdgeNetwork``'s, so ``SchedulingPolicy`` and the Theorem-2/4
bandwidth allocators work per cell unchanged.

Heterogeneous radio resources: each BS owns its own uplink budget
``cell_bw[c]`` (``resolve_cell_bandwidth`` broadcasts a scalar or validates
a per-cell vector; the empty spec reproduces the legacy behaviour where
every cell owns the full system bandwidth).  Association is either

* ``nearest``     — pure nearest-BS (the bitwise-identical default), or
* ``load_aware``  — best-response iteration on an effective distance
  ``d(u, c) + load_penalty_m · members_c / fair_share_c`` with the fair
  share proportional to the cell's bandwidth budget: hot (or skinny-budget)
  cells shed UEs to neighbours, which changes the handover dynamics
  (cf. the macro/micro setting of arXiv:2303.10580).

RNG discipline — two independent streams:

* ``rng``      (main, ``default_rng(seed)``): consumed in exactly the order
  ``EdgeNetwork.drop`` consumes it (distance radii, CPU frequencies, then
  Rayleigh fading per ``sample_fading``), so a 1-cell static drop is
  **bitwise identical** to the legacy network for the same seed.
* ``mob_rng``  (auxiliary): drop angles, multi-cell positions, and all
  mobility-model draws — extra geometry never perturbs the fading stream.

``advance_to(t)`` runs the simulation clock.  Two properties keep its
amortized per-call cost O(1) even though the event loop calls it once per
heap pop (tens of thousands of times per run):

* **Grid-aligned ticks** — integration steps live on the global
  ``step_s`` grid (tick ``j`` covers ``[j·step_s, (j+1)·step_s)``), and an
  advance integrates all newly-completed ticks with one batched
  ``[ticks, n, D]`` RNG draw (``MobilityModel.step_many``).  Positions —
  and hence the mobility RNG schedule — are a pure function of *which*
  ticks have elapsed, never of how the event loop grouped them into calls
  (``advance_to(t1); advance_to(t2)`` ≡ ``advance_to(t2)`` bitwise).
  Calls that complete no tick are pure clock updates.
* **Safe-radius re-association** — every re-score records a per-UE
  handover margin (half the gap to the runner-up BS, in metres); on later
  ticks only UEs whose displacement since their last score reaches that
  margin are re-scored against the full BS list.  Exact for ``nearest``
  by the triangle inequality; for ``load_aware`` the margin is measured
  on *effective* cost and gates whether the best-response recompute runs
  at all (loads can only change through a recompute, so an all-safe tick
  is provably a fixpoint).  ``reassoc="full"`` forces the legacy
  every-tick ``[n, k]`` recompute — both modes are pinned bitwise
  identical in ``tests/test_sim_clock.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.config import WirelessConfig
from repro.core.bandwidth import UEChannel
from repro.mobility.models import Area, MobilityModel, get_mobility
from repro.obs import trace as obs
from repro.wireless.channel import (CounterFadingMixin, make_channel,
                                    mean_rates_for, validate_rng_mode)

MIN_DIST_M = 5.0        # same floor as EdgeNetwork.drop
_MOB_STREAM = 0x6D6F62  # "mob" — decorrelates the auxiliary stream


def resolve_cell_bandwidth(spec, n_cells: int, default_hz: float
                           ) -> np.ndarray:
    """Per-cell uplink budgets [Hz] from a ``MobilityConfig.cell_bandwidth_hz``
    spec: ``()``/``None`` → every cell owns ``default_hz`` (legacy), one
    value → broadcast, else exactly one positive entry per cell."""
    if spec is None:
        spec = ()
    arr = np.asarray(spec, dtype=np.float64).reshape(-1)
    if arr.size == 0:
        arr = np.full(n_cells, float(default_hz))
    elif arr.size == 1:
        arr = np.full(n_cells, float(arr[0]))
    elif arr.size != n_cells:
        raise ValueError(f"cell_bandwidth_hz has {arr.size} entries for "
                         f"{n_cells} cells (want 0, 1, or {n_cells})")
    else:
        arr = arr.copy()
    if not np.all(arr > 0):
        raise ValueError(f"cell bandwidth budgets must be positive, got {arr}")
    return arr


def cell_layout(n_cells: int, radius_m: float) -> np.ndarray:
    """BS coordinates [n_cells, 2] on a hex-ish grid (col pitch √3·R, row
    pitch 1.5·R, odd rows offset half a column)."""
    if n_cells < 1:
        raise ValueError("need at least one cell")
    col_pitch = np.sqrt(3.0) * radius_m
    row_pitch = 1.5 * radius_m
    cols = int(np.ceil(np.sqrt(n_cells)))
    xy = np.empty((n_cells, 2))
    for k in range(n_cells):
        r, c = divmod(k, cols)
        xy[k, 0] = c * col_pitch + (0.5 * col_pitch if r % 2 else 0.0)
        xy[k, 1] = r * row_pitch
    return xy


@dataclass
class MultiCellNetwork(CounterFadingMixin):
    """Time-varying geometry: positions, nearest-BS association, handovers."""
    cfg: WirelessConfig
    n_ues: int
    bs_xy: np.ndarray                 # [n_cells, 2]
    positions: np.ndarray             # [n_ues, 2]
    cpu_freq: np.ndarray              # [n_ues] Hz
    rng: np.random.Generator          # main stream (fading)
    mob_rng: np.random.Generator      # auxiliary stream (geometry/mobility)
    mobility: MobilityModel
    area: Area
    assoc: np.ndarray                 # [n_ues] serving cell index
    _dist: np.ndarray                 # [n_ues] distance to serving BS [m]
    _mob_state: dict = field(default_factory=dict)
    time: float = 0.0                 # simulated seconds advanced so far
    handovers: int = 0                # lifetime handover count
    step_s: float = 1.0               # mobility integration step
    cell_bw: np.ndarray = None        # [n_cells] uplink budget per BS [Hz]
    association: str = "nearest"      # nearest | load_aware
    load_penalty_m: float = 50.0      # effective metres per unit rel. load
    reassoc: str = "safe_radius"      # safe_radius | full (exact reference)
    _ticks: int = 0                   # completed step_s grid ticks
    _anchor: np.ndarray = None        # [n, 2] position at last re-score
    _margin: np.ndarray = None        # [n] safe handover radius [m]
    _la_converged: bool = False       # load_aware best response at fixpoint
    # open-world scenario: which UEs currently exist.  ``None`` (default,
    # closed world) keeps every legacy code path untouched; when set,
    # membership queries and handover events see only active UEs —
    # positions/association still advance for everyone, so a dormant UE
    # re-joins wherever its trajectory carried it.
    active: np.ndarray = None         # [n_ues] bool, or None

    # ------------------------------------------------------------------
    @classmethod
    def drop(cls, cfg: WirelessConfig, n_ues: int, *, n_cells: int = 1,
             seed: int = 0, mobility: str = "static", speed_mps: float = 0.0,
             pause_s: float = 0.0, gm_alpha: float = 0.85,
             uniform_distance: bool = False, step_s: float = 1.0,
             cell_bandwidth_hz=None, association: str = "nearest",
             load_penalty_m: float = 50.0,
             reassoc: str = "safe_radius") -> "MultiCellNetwork":
        if step_s <= 0.0:
            raise ValueError(f"step_s must be positive, got {step_s}")
        validate_rng_mode(cfg.rng)
        if association not in ("nearest", "load_aware"):
            raise ValueError(f"unknown association policy {association!r}; "
                             f"known: ['load_aware', 'nearest']")
        if reassoc not in ("safe_radius", "full"):
            raise ValueError(f"unknown reassoc mode {reassoc!r}; "
                             f"known: ['full', 'safe_radius']")
        cell_bw = resolve_cell_bandwidth(cell_bandwidth_hz, n_cells,
                                         cfg.total_bandwidth_hz)
        rng = np.random.default_rng(seed)
        mob_rng = np.random.default_rng([seed, _MOB_STREAM])
        bs_xy = cell_layout(n_cells, cfg.cell_radius_m)
        r_cell = cfg.cell_radius_m
        area = Area(float(bs_xy[:, 0].min() - r_cell),
                    float(bs_xy[:, 1].min() - r_cell),
                    float(bs_xy[:, 0].max() + r_cell),
                    float(bs_xy[:, 1].max() + r_cell))

        if n_cells == 1:
            # main-stream consumption mirrors EdgeNetwork.drop exactly; the
            # polar angle comes from the auxiliary stream so fading draws
            # that follow are unperturbed
            if uniform_distance:
                radii = np.full(n_ues, r_cell / 2.0)
            else:
                radii = np.maximum(
                    r_cell * np.sqrt(rng.uniform(size=n_ues)), MIN_DIST_M)
            theta = mob_rng.uniform(0.0, 2.0 * np.pi, size=n_ues)
            positions = bs_xy[0] + radii[:, None] * np.stack(
                [np.cos(theta), np.sin(theta)], axis=1)
            dist0 = radii                  # exact (no norm round-trip)
            assoc = np.zeros(n_ues, dtype=np.int64)
        elif uniform_distance:
            # equal-η ablation in a multi-cell drop: ring of radius R/2
            # around an auxiliary-stream home cell
            home = mob_rng.integers(0, n_cells, size=n_ues)
            theta = mob_rng.uniform(0.0, 2.0 * np.pi, size=n_ues)
            positions = bs_xy[home] + (r_cell / 2.0) * np.stack(
                [np.cos(theta), np.sin(theta)], axis=1)
            assoc, dist0 = _run_association(positions, bs_xy, association,
                                            cell_bw, load_penalty_m)
        else:
            positions = area.uniform(mob_rng, n_ues)
            assoc, dist0 = _run_association(positions, bs_xy, association,
                                            cell_bw, load_penalty_m)

        ratio = max(cfg.cpu_hetero, 1.0)
        cpu = cfg.cpu_freq_hz * np.exp(
            rng.uniform(np.log(1.0 / ratio), 0.0, size=n_ues))

        model = get_mobility(mobility, speed_mps=speed_mps, pause_s=pause_s,
                             gm_alpha=gm_alpha)
        net = cls(cfg=cfg, n_ues=n_ues, bs_xy=bs_xy, positions=positions,
                  cpu_freq=cpu, rng=rng, mob_rng=mob_rng, mobility=model,
                  area=area, assoc=assoc, _dist=dist0, step_s=step_s,
                  cell_bw=cell_bw, association=association,
                  load_penalty_m=load_penalty_m, reassoc=reassoc)
        net._mob_state = model.init_state(n_ues, area, mob_rng)
        net._init_counter_fading(seed, n_ues)
        # safe-radius bookkeeping: zero margins force the first moving tick
        # to re-score everyone (and establish real margins); until a
        # load_aware best response is observed at a fixpoint its margins
        # cannot be trusted, so _la_converged starts False
        net._anchor = positions.copy()
        net._margin = np.zeros(n_ues)
        return net

    # ------------------------------------------------------------------
    # channel API (EdgeNetwork-compatible)
    # ------------------------------------------------------------------
    @property
    def n_cells(self) -> int:
        return len(self.bs_xy)

    @property
    def distances(self) -> np.ndarray:
        """Distance to the *serving* BS per UE [m]."""
        return self._dist

    def sample_fading(self) -> np.ndarray:
        """Rayleigh small-scale coefficients for one round (main stream —
        the same draw ``EdgeNetwork.sample_fading`` makes)."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=self.n_ues)

    def sample_fading_batch(self, k: int) -> np.ndarray:
        """``k`` successive fading draws as one ``[k, n]`` main-stream RNG
        call — bitwise identical to the loop (see
        ``EdgeNetwork.sample_fading_batch``)."""
        return self.rng.rayleigh(scale=self.cfg.rayleigh_scale,
                                 size=(k, self.n_ues))

    def channel(self, ue: int, h: Optional[float] = None) -> UEChannel:
        hval = float(h) if h is not None else float(self.sample_fading()[ue])
        return make_channel(self.cfg, self._dist[ue], hval)

    def channels(self, h: Optional[np.ndarray] = None) -> list:
        h = h if h is not None else self.sample_fading()
        return [self.channel(i, h[i]) for i in range(self.n_ues)]

    def mean_rates(self, bandwidth_per_ue: Optional[float] = None
                   ) -> np.ndarray:
        """Expected uplink rate at equal-split bandwidth (policy input)."""
        return mean_rates_for(self.cfg, self._dist, bandwidth_per_ue)

    # ------------------------------------------------------------------
    # cells
    # ------------------------------------------------------------------
    def cell_members(self, c: int) -> np.ndarray:
        if self.active is None:
            return np.nonzero(self.assoc == c)[0]
        return np.nonzero((self.assoc == c) & self.active)[0]

    def cell_counts(self) -> np.ndarray:
        if self.active is None:
            return np.bincount(self.assoc, minlength=self.n_cells)
        return np.bincount(self.assoc[self.active],
                           minlength=self.n_cells)

    # ------------------------------------------------------------------
    # open-world scenario hooks
    # ------------------------------------------------------------------
    def set_active(self, ue: int, flag: bool) -> None:
        """Flip one UE's existence bit (lazily materialises the mask)."""
        if self.active is None:
            self.active = np.ones(self.n_ues, dtype=bool)
        self.active[ue] = flag

    def retarget_waypoints(self, idx: np.ndarray, cell: int,
                           spread_m: float,
                           rng: np.random.Generator) -> int:
        """Flash crowd: point the random waypoints of ``idx`` at a spot
        near BS ``cell`` — their next legs converge on the hotspot.  Draws
        from the caller's ``rng`` (the scenario stream), never from
        ``mob_rng``, so the mobility draw schedule of every other UE is
        untouched.  No-op (returns 0) for mobility models without
        waypoint state."""
        wp = self._mob_state.get("waypoint")
        if wp is None or len(idx) == 0:
            return 0
        tgt = self.bs_xy[cell] + rng.normal(0.0, spread_m,
                                            size=(len(idx), 2))
        np.clip(tgt[:, 0], self.area.xmin, self.area.xmax, out=tgt[:, 0])
        np.clip(tgt[:, 1], self.area.ymin, self.area.ymax, out=tgt[:, 1])
        wp[idx] = tgt
        return len(idx)

    # ------------------------------------------------------------------
    # time
    # ------------------------------------------------------------------
    def advance_to(self, t: float) -> List[Tuple[int, int, int]]:
        """Advance the simulation clock to ``t``; integrate any newly
        completed ``step_s`` grid ticks, refresh association, and return
        the handover events ``[(ue, src, dst), ...]`` this advance caused.

        Static mobility (or a zero/negative time step) is a pure clock
        update — positions, distances and association stay exactly as
        dropped, which is what keeps the degenerate configuration bitwise
        identical to the legacy single-cell path.  So is any advance that
        completes no new grid tick — the O(1)-amortized common case when
        the event loop calls this once per heap pop.
        """
        if t <= self.time or self.mobility.is_static:
            self.time = max(self.time, t)
            return []
        self.time = t
        target = int(math.floor(t / self.step_s + 1e-9))
        if target <= self._ticks:
            return []
        # tracing lives only in this (rare) tick branch — the per-heap-pop
        # no-new-tick calls above stay free of instrumentation
        tr = obs.CURRENT
        tr.add("mobility.ticks", target - self._ticks)
        with tr.span("mobility"):
            self.positions, self._mob_state = self.mobility.step_many(
                self.positions, self._mob_state, target - self._ticks,
                self.step_s, self.area, self.mob_rng)
        self._ticks = target
        with tr.span("reassociate"):
            new_assoc = self._reassociate()
        moved = np.nonzero(new_assoc != self.assoc)[0]
        if self.active is not None:
            # dormant UEs keep moving and re-associating silently — no
            # handover events (they are nobody's member) and no count;
            # a later join simply finds them in their current cell
            moved = moved[self.active[moved]]
        events = [(int(u), int(self.assoc[u]), int(new_assoc[u]))
                  for u in moved]
        self.handovers += len(events)
        if events:
            tr.add("mobility.handovers", len(events))
        self.assoc = new_assoc
        return events

    # ------------------------------------------------------------------
    # association refresh (safe-radius incremental, or full reference)
    # ------------------------------------------------------------------
    def _serving_dist(self, assoc: np.ndarray) -> np.ndarray:
        """Serving-BS distance per UE from current positions — the same
        x² + y² → sqrt arithmetic as selecting the serving column of the
        full ``[n, k]`` matrix, so the values are bitwise identical."""
        return np.maximum(
            np.sqrt(((self.positions - self.bs_xy[assoc]) ** 2).sum(-1)),
            MIN_DIST_M)

    def _reassociate(self) -> np.ndarray:
        if self.reassoc == "full":
            new_assoc, self._dist = _run_association(
                self.positions, self.bs_xy, self.association, self.cell_bw,
                self.load_penalty_m, assoc0=self.assoc)
            return new_assoc
        if self.association == "nearest":
            return self._reassoc_nearest()
        return self._reassoc_load_aware()

    def _reassoc_nearest(self) -> np.ndarray:
        """Exact incremental nearest-BS: only UEs displaced past their
        safe radius since their last score can have changed argmin (by the
        triangle inequality: every other BS is still ≥ 2·margin − 2·disp
        farther), so only those rows are re-scored against the BS list."""
        pos, bs = self.positions, self.bs_xy
        new_assoc = self.assoc
        if self.n_cells > 1:
            disp_sq = ((pos - self._anchor) ** 2).sum(-1)
            cand = np.nonzero(disp_sq >= self._margin * self._margin)[0]
            if len(cand):
                obs.CURRENT.add("mobility.rescored", len(cand))
                d2 = ((pos[cand, None, :] - bs[None, :, :]) ** 2).sum(-1)
                new_assoc = self.assoc.copy()
                new_assoc[cand] = d2.argmin(axis=1).astype(np.int64)
                two = np.partition(np.sqrt(d2), 1, axis=1)
                self._margin[cand] = (two[:, 1] - two[:, 0]) / 2.0
                self._anchor[cand] = pos[cand]
        # serving distance tracks every tick (it prices upload times)
        self._dist = self._serving_dist(new_assoc)
        return new_assoc

    def _reassoc_load_aware(self) -> np.ndarray:
        """Safe-radius-gated load-aware refresh.  Margins are half the
        effective-cost gap to the runner-up cell at the last best-response
        fixpoint.  While no UE has moved past its margin, loads are
        unchanged (they only change through a recompute) and each UE's own
        column drifted by < margin, so every UE is still at its strict
        argmin — the full best response would move nobody — and the
        ``[n, k]`` recompute is skipped.  Any breach (or a non-converged
        previous pass, whose margins are meaningless) runs the full
        recompute and re-anchors everyone."""
        pos = self.positions
        if self._la_converged:
            disp_sq = ((pos - self._anchor) ** 2).sum(-1)
            if not np.any(disp_sq >= self._margin * self._margin):
                obs.CURRENT.add("mobility.load_aware_skips")
                self._dist = self._serving_dist(self.assoc)
                return self.assoc
        obs.CURRENT.add("mobility.load_aware_recomputes")
        info: dict = {}
        new_assoc, self._dist = _associate_load_aware(
            pos, self.bs_xy, self.cell_bw, self.load_penalty_m,
            assoc0=self.assoc, info=info)
        self._margin = info["margin"]
        self._la_converged = bool(info["converged"])
        self._anchor = pos.copy()
        return new_assoc


def _associate(positions: np.ndarray, bs_xy: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Nearest-BS association: [n] cell ids + [n] serving distances."""
    d2 = ((positions[:, None, :] - bs_xy[None, :, :]) ** 2).sum(-1)
    assoc = d2.argmin(axis=1).astype(np.int64)
    dist = np.maximum(np.sqrt(d2[np.arange(len(positions)), assoc]),
                      MIN_DIST_M)
    return assoc, dist


def _associate_load_aware(positions: np.ndarray, bs_xy: np.ndarray,
                          cell_bw: np.ndarray, penalty_m: float,
                          assoc0: Optional[np.ndarray] = None,
                          passes: int = 2,
                          info: Optional[dict] = None
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Load-aware association: best response on the effective distance
    ``d(u, c) + penalty_m · members_c / fair_c`` with the fair share
    ``fair_c = n · cell_bw_c / Σ cell_bw`` proportional to the cell's
    bandwidth budget — hot (or skinny-budget) cells price themselves up
    and shed UEs.

    Two details make the dynamics well-behaved:

    * **strict improvement with self-exclusion** — a UE evaluating its own
      cell excludes itself from that cell's load, and only moves when the
      alternative is *strictly* cheaper (hysteresis: an unchanged geometry
      re-associates to exactly the same assignment, so a lazy re-run never
      manufactures handovers);
    * **chunked updates** — simultaneous best response oscillates (every
      member of a hot cell sees the same cheaper neighbour and the whole
      cell migrates en masse, then back).  Re-deciding in index chunks of
      ``~n/4k`` with load counts refreshed between chunks keeps the
      overshoot bounded by one chunk while staying vectorized; for small n
      the chunk is a single UE, i.e. exact sequential best response.

    Deterministic (fixed UE order, no RNG), starts from the previous
    association (or nearest-BS on a fresh drop), and runs a fixed number
    of ``passes`` over the population.

    When ``info`` is supplied it is filled with the safe-radius gating
    state: ``info["converged"]`` — whether a full pass observed no moves
    (the assignment is a best-response fixpoint), and ``info["margin"]``
    — per-UE half effective-cost gap to the runner-up cell, i.e. how far
    a UE may drift before its strict argmin could change while loads stay
    frozen.
    """
    n, k = len(positions), len(bs_xy)
    d = np.sqrt(((positions[:, None, :] - bs_xy[None, :, :]) ** 2).sum(-1))
    fair = n * cell_bw / cell_bw.sum()          # expected members per cell
    unit = penalty_m / np.maximum(fair, 1e-12)  # metres per member, per cell
    assoc = (d.argmin(axis=1).astype(np.int64) if assoc0 is None
             else np.asarray(assoc0, dtype=np.int64).copy())
    counts = np.bincount(assoc, minlength=k).astype(np.float64)
    chunk = max(1, n // (4 * k))
    converged = False
    for _ in range(passes):
        moved = 0
        for start in range(0, n, chunk):
            rows = np.arange(start, min(start + chunk, n))
            cur = assoc[rows]
            cost = d[rows] + unit[None, :] * counts[None, :]
            cost[np.arange(len(rows)), cur] -= unit[cur]   # exclude self
            best = cost.argmin(axis=1).astype(np.int64)
            better = cost[np.arange(len(rows)), best] \
                < cost[np.arange(len(rows)), cur]
            new = np.where(better, best, cur)
            if np.any(new != cur):
                counts += np.bincount(new, minlength=k) \
                    - np.bincount(cur, minlength=k)
                assoc[rows] = new
                moved += int((new != cur).sum())
        if moved == 0:
            converged = True
            break
    dist = np.maximum(d[np.arange(n), assoc], MIN_DIST_M)
    if info is not None:
        rows = np.arange(n)
        cost = d + unit[None, :] * counts[None, :]
        cost[rows, assoc] -= unit[assoc]                   # exclude self
        own = cost[rows, assoc].copy()
        cost[rows, assoc] = np.inf
        alt = cost.min(axis=1)          # k == 1 → inf → infinite margin
        info["margin"] = np.maximum((alt - own) / 2.0, 0.0)
        info["converged"] = converged
    return assoc, dist


def _run_association(positions: np.ndarray, bs_xy: np.ndarray,
                     association: str, cell_bw: np.ndarray, penalty_m: float,
                     assoc0: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Dispatch on the association policy (``nearest`` stays the exact
    legacy code path, bit for bit)."""
    if association == "nearest":
        return _associate(positions, bs_xy)
    return _associate_load_aware(positions, bs_xy, cell_bw, penalty_m,
                                 assoc0=assoc0)
