"""Vectorized UE mobility models.

All models advance an ``[n, 2]`` position array with pure array math — no
Python per-UE loops — so a 10k-UE network costs the same handful of numpy
ops as a 10-UE one.  The canonical entry point is ``step_many``: advance
``ticks`` integration steps of ``dt`` simulated seconds each, drawing all
the randomness those ticks need as ONE batched ``[ticks, n, D]``-shaped RNG
call up front (``step`` is the ``ticks=1`` special case).

Draw-schedule discipline: every tick consumes exactly one contiguous block
of ``n·D`` variates from the caller's generator, in tick order.  Because
numpy Generators fill arrays from the bitstream sequentially regardless of
shape, ``step_many(ticks=T)`` is **bitwise identical** to ``T`` successive
``step`` calls — the trajectory depends only on *which grid ticks elapsed*,
never on how the caller grouped them into ``advance_to`` calls (pinned by
``tests/test_sim_clock.py``).  Draws are applied with ``np.where`` masks,
so the count never depends on which UEs happened to arrive at a waypoint.

* ``StaticMobility``     — positions never move (the original single-cell
                           drop); draws nothing.
* ``RandomWaypoint``     — each UE walks toward a uniformly-drawn waypoint
                           at a per-leg speed ``U[0.5, 1.5]·v̄``, pauses
                           ``pause_s``, redraws.
* ``GaussMarkov``        — speed/heading follow an AR(1) around per-UE
                           means; reflects (position and heading) at the
                           area boundary.

``get_mobility`` resolves a config string; any model at ``speed_mps ≤ 0``
collapses to ``StaticMobility``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

State = Dict[str, np.ndarray]

# max doubles one batched step_many draw may materialise (~32 MB); a long
# inter-event gap at 16k UEs would otherwise allocate GBs in one RNG call.
# Blocks are bitwise the single big draw (sequential bitstream).
MAX_DRAW_DOUBLES = 1 << 22


def _tick_draws(ticks: int, n: int, d: int, draw):
    """Yield one ``[n, d]`` random slab per tick, drawn in blocks of at
    most ``MAX_DRAW_DOUBLES`` doubles via ``draw(size=...)``.  numpy
    Generators consume the bitstream sequentially regardless of shape, so
    the slabs are bitwise one unbounded ``[ticks, n, d]`` call — and
    bitwise per-tick ``[1, n, d]`` calls (the schedule-independence
    invariant) — without the unbounded allocation."""
    block = max(1, MAX_DRAW_DOUBLES // max(d * n, 1))
    for start in range(0, ticks, block):
        yield from draw(size=(min(block, ticks - start), n, d))


@dataclass(frozen=True)
class Area:
    """Axis-aligned rectangle the UEs roam in."""
    xmin: float
    ymin: float
    xmax: float
    ymax: float

    @property
    def lo(self) -> np.ndarray:
        return np.array([self.xmin, self.ymin])

    @property
    def hi(self) -> np.ndarray:
        return np.array([self.xmax, self.ymax])

    def uniform(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.uniform(self.lo, self.hi, size=(n, 2))

    def contains(self, pos: np.ndarray, tol: float = 1e-6) -> np.ndarray:
        return ((pos >= self.lo - tol) & (pos <= self.hi + tol)).all(axis=-1)


class MobilityModel:
    """Protocol: ``init_state`` once per drop, ``step_many`` per advance."""

    def init_state(self, n: int, area: Area,
                   rng: np.random.Generator) -> State:
        return {}

    def step(self, pos: np.ndarray, state: State, dt: float, area: Area,
             rng: np.random.Generator) -> Tuple[np.ndarray, State]:
        """One tick — the ``ticks=1`` case of ``step_many``."""
        return self.step_many(pos, state, 1, dt, area, rng)

    def step_many(self, pos: np.ndarray, state: State, ticks: int,
                  dt: float, area: Area, rng: np.random.Generator
                  ) -> Tuple[np.ndarray, State]:
        raise NotImplementedError

    @property
    def is_static(self) -> bool:
        return False


class StaticMobility(MobilityModel):
    """No movement, no RNG consumption — the original frozen geometry."""

    def step_many(self, pos, state, ticks, dt, area, rng):
        return pos, state

    @property
    def is_static(self) -> bool:
        return True


@dataclass(frozen=True)
class RandomWaypoint(MobilityModel):
    """Classic RWP: walk → (optional pause) → new waypoint, vectorized.

    Per tick: one contiguous ``[n, 3]`` uniform block — waypoint x/y and
    the replacement leg speed (used only on lanes that arrive this tick).
    """

    speed_mps: float
    pause_s: float = 0.0

    def _leg_speed(self, u: np.ndarray) -> np.ndarray:
        """Per-leg speed from a pre-drawn U[0, 1) block: U[0.5, 1.5]·v̄."""
        return self.speed_mps * (0.5 + u)

    def _draw_speed(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return self._leg_speed(rng.random(size=n))

    def init_state(self, n: int, area: Area,
                   rng: np.random.Generator) -> State:
        return {"waypoint": area.uniform(rng, n),
                "speed": self._draw_speed(rng, n),
                "pause": np.zeros(n)}

    def step_many(self, pos, state, ticks, dt, area, rng):
        lo, span = area.lo, area.hi - area.lo
        waypoint, speed, pause = (state["waypoint"], state["speed"],
                                  state["pause"])
        for u in _tick_draws(ticks, len(pos), 3, rng.random):
            new_wp = lo + u[:, :2] * span
            new_speed = self._leg_speed(u[:, 2])

            moving = pause <= 0.0
            vec = waypoint - pos
            dist = np.linalg.norm(vec, axis=1)
            step_len = speed * dt
            arrive = moving & (dist <= step_len)
            # unit direction, safe where dist == 0
            unit = vec / np.maximum(dist, 1e-12)[:, None]
            walked = pos + unit * np.minimum(step_len, dist)[:, None]
            pos = np.where((moving & ~arrive)[:, None], walked, pos)
            pos = np.where(arrive[:, None], waypoint, pos)

            waypoint = np.where(arrive[:, None], new_wp, waypoint)
            speed = np.where(arrive, new_speed, speed)
            pause = np.where(arrive, self.pause_s, np.maximum(pause - dt, 0.0))
        return pos, {"waypoint": waypoint, "speed": speed, "pause": pause}


@dataclass(frozen=True)
class GaussMarkov(MobilityModel):
    """AR(1) speed/heading (Camp et al.): s ← αs + (1−α)s̄ + √(1−α²)·σ·w.

    Per tick: one contiguous ``[n, 2]`` standard-normal block (speed and
    heading innovations).
    """

    speed_mps: float
    alpha: float = 0.85
    speed_std_frac: float = 0.25     # σ_s = frac · s̄
    heading_std: float = 0.5         # σ_θ [rad]

    def init_state(self, n: int, area: Area,
                   rng: np.random.Generator) -> State:
        theta = rng.uniform(0.0, 2.0 * np.pi, size=n)
        return {"speed": np.full(n, self.speed_mps),
                "theta": theta.copy(),
                "mean_theta": theta}

    def step_many(self, pos, state, ticks, dt, area, rng):
        a = self.alpha
        noise = np.sqrt(max(1.0 - a * a, 0.0))
        speed, theta = state["speed"], state["theta"]
        mean_theta = state["mean_theta"]
        lo, hi = area.lo, area.hi
        for w in _tick_draws(ticks, len(pos), 2, rng.standard_normal):
            speed = (a * speed + (1.0 - a) * self.speed_mps
                     + noise * self.speed_std_frac * self.speed_mps
                     * w[:, 0])
            speed = np.maximum(speed, 0.0)
            theta = (a * theta + (1.0 - a) * mean_theta
                     + noise * self.heading_std * w[:, 1])

            pos = pos + dt * speed[:, None] * np.stack(
                [np.cos(theta), np.sin(theta)], axis=1)
            # reflect at the boundary (position and heading)
            under, over = pos < lo, pos > hi
            pos = np.where(under, 2.0 * lo - pos, pos)
            pos = np.where(over, 2.0 * hi - pos, pos)
            pos = np.clip(pos, lo, hi)           # guard: step longer than area
            flip_x = under[:, 0] | over[:, 0]
            flip_y = under[:, 1] | over[:, 1]
            theta = np.where(flip_x, np.pi - theta, theta)
            theta = np.where(flip_y, -theta, theta)
        return pos, {"speed": speed, "theta": theta,
                     "mean_theta": mean_theta}


def get_mobility(name: str, *, speed_mps: float, pause_s: float = 0.0,
                 gm_alpha: float = 0.85) -> MobilityModel:
    """Resolve a ``MobilityConfig.model`` string to a model instance."""
    if speed_mps <= 0.0 or name == "static":
        return StaticMobility()
    if name == "random_waypoint":
        return RandomWaypoint(speed_mps=speed_mps, pause_s=pause_s)
    if name in ("gauss_markov", "gauss-markov"):
        return GaussMarkov(speed_mps=speed_mps, alpha=gm_alpha)
    raise ValueError(f"unknown mobility model {name!r}; "
                     f"known: static, random_waypoint, gauss_markov")
