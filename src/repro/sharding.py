"""Logical-axis sharding rules → ``PartitionSpec``/``NamedSharding``.

The model code never mentions physical mesh axes.  It tags tensors/params with
*logical* axis names ("batch", "heads", "ffn", "experts", "vocab", "embed", ...)
and this module maps them onto whatever physical mesh is active:

  single-pod  : (data=16, model=16)
  multi-pod   : (pod=2, data=16, model=16)

The mapping table is itself a config-level object (``AxisRules``) so the perf
pass can swap sharding strategies without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class AxisRules:
    """Logical-name → tuple of candidate physical axes.

    For each logical axis we keep an ordered tuple of physical axes; at spec
    resolution time the first subset of axes present in the active mesh (and
    not already consumed by another dimension of the same tensor) is used.
    """
    rules: dict = field(default_factory=lambda: dict(
        # --- activations ---
        batch=("pod", "data"),
        seq=(),                      # sequence replicated by default
        act_embed=(),                # activation d_model replicated
        act_heads=("model",),        # attention activations split by head
        act_ffn=("model",),
        cache_batch=("data",),
        cache_seq=(),                # decode cache sequence dim
        cache_heads=("model",),
        # --- parameters (2-D sharded: feature->model, embed->data ZeRO-style) ---
        embed=("data",),             # d_model dim of weights
        heads=("model",),            # q/o head dims
        kv_heads=("model",),
        ffn=("model",),              # FFN hidden
        experts=("model",),          # MoE expert dim
        vocab=("model",),
        ssm_inner=("model",),        # mamba d_inner
        lru=("model",),              # rg-lru width
        mla_rank=(),                 # MLA latent kept replicated
        layers=(),                   # stacked scan-layer dim
        # --- FL / client axis ---
        clients=("pod",),            # semi-sync cohort axis
    ))

    def with_overrides(self, **kw) -> "AxisRules":
        d = dict(self.rules)
        d.update(kw)
        return replace(self, rules=d)


class _ShardingCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: AxisRules = AxisRules()


_CTX = _ShardingCtx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    """Activate a mesh + rule set for spec resolution (and as jit context)."""
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> AxisRules:
    return _CTX.rules


def logical_spec(names: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[AxisRules] = None) -> P:
    """Resolve a sequence of logical axis names to a PartitionSpec.

    Physical axes already used by an earlier dimension of the same tensor are
    skipped (a mesh axis may shard at most one dim).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P(*([None] * len(names)))
    avail = set(mesh.axis_names)
    used: set = set()
    out = []
    for name in names:
        if name is None:
            out.append(None)
            continue
        cand = rules.rules.get(name, ())
        picked = tuple(a for a in cand if a in avail and a not in used)
        used.update(picked)
        if len(picked) == 0:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return P(*out)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` against logical axes; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_spec(names, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(names, mesh))


# ---------------------------------------------------------------------------
# Parameter spec resolution by pytree path
# ---------------------------------------------------------------------------

# Ordered (key-substring → logical axes per trailing dims) rules.  The logical
# names are matched against the *last* len(names) dims of the parameter; any
# leading dims (e.g. the stacked scan-layer dim) get the "layers" rule (= None).
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("tok_embed",        ("vocab", "embed")),
    ("pos_embed",        (None, "embed")),
    ("lm_head",          ("embed", "vocab")),
    # attention
    ("w_q",              ("embed", "heads")),
    ("w_k",              ("embed", "kv_heads")),
    ("w_v",              ("embed", "kv_heads")),
    ("w_o",              ("heads", "embed")),
    # MLA
    ("w_dq",             ("embed", "mla_rank")),
    ("w_uq",             ("mla_rank", "heads")),
    ("w_dkv",            ("embed", "mla_rank")),
    ("w_kr",             ("embed", None)),
    ("w_uk",             ("mla_rank", "heads")),
    ("w_uv",             ("mla_rank", "heads")),
    # dense mlp
    ("w_gate",           ("embed", "ffn")),
    ("w_up",             ("embed", "ffn")),
    ("w_down",           ("ffn", "embed")),
    # moe
    ("router",           ("embed", "experts")),
    ("moe_gate",         ("experts", "embed", "ffn")),
    ("moe_up",           ("experts", "embed", "ffn")),
    ("moe_down",         ("experts", "ffn", "embed")),
    ("shared_gate",      ("embed", "ffn")),
    ("shared_up",        ("embed", "ffn")),
    ("shared_down",      ("ffn", "embed")),
    # ssm (mamba2)
    ("in_proj",          ("embed", "ssm_inner")),
    ("out_proj",         ("ssm_inner", "embed")),
    ("conv_w",           (None, "ssm_inner")),
    ("conv_b",           ("ssm_inner",)),
    ("A_log",            (None,)),
    ("dt_bias",          (None,)),
    ("D_skip",           (None,)),
    # rg-lru / hybrid
    ("lru_in",           ("embed", "lru")),
    ("lru_out",          ("lru", "embed")),
    ("lru_a",            ("lru",)),
    ("lru_gate",         (None, "lru")),
    # lstm / small models — replicated
    ("lstm",             ()),
    ("conv",             ()),
    ("dense",            ()),
    ("bias",             ()),
    # norms — replicated
    ("scale",            ()),
    ("norm",             ()),
)


def param_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter given its pytree path string + rank."""
    for key, names in _PARAM_RULES:
        if key in path:
            names = tuple(names)[-ndim:] if len(names) > ndim else names
            lead = ndim - len(names)
            return ("layers",) * lead + tuple(names)
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Optional[Mesh] = None,
                rules: Optional[AxisRules] = None):
    """PartitionSpec pytree matching ``params`` (by path-name rules)."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules

    def spec_for(path, leaf):
        names = param_logical_axes(_path_str(path), leaf.ndim)
        return logical_spec(names, mesh, rules)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_shardings(params, mesh: Optional[Mesh] = None,
                    rules: Optional[AxisRules] = None):
    """NamedSharding pytree for params (None tree if no mesh)."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return jax.tree.map(lambda _: None, params)
    specs = param_specs(params, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
